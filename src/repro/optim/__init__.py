"""Minimal functional optimizer library (no optax in this environment).

API mirrors optax: an optimizer is a (init_fn, update_fn) pair where
  state = init_fn(params)
  updates, state = update_fn(grads, state, params)
  params = apply_updates(params, updates)
Updates are *added* to params (sign convention: update = -lr * direction).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ------------------------------------------------------------------
# SGD (+ momentum / Nesterov) — DiLoCo's outer optimizer
# ------------------------------------------------------------------

def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return {"m": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state
        m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(
                lambda m_, g: -lr * (momentum * m_ + g.astype(jnp.float32)),
                m, grads)
        else:
            upd = jax.tree.map(lambda m_: -lr * m_, m)
        return upd, {"m": m}

    return Optimizer(init, update)


def nesterov_outer(lr: float, momentum: float = 0.9) -> Optimizer:
    """DiLoCo's outer optimizer: Nesterov momentum SGD applied to the
    averaged pseudo-gradient (delta)."""
    return sgd(lr, momentum=momentum, nesterov=True)


def delay_compensated_nesterov(lr: float, momentum: float = 0.9) -> Optimizer:
    """Staleness-aware Nesterov for delayed (async) outer application.

    Under one-round-stale pseudo-gradients the effective momentum of
    plain Nesterov compounds across the staleness window and 0.9 is
    underdamped (the documented ``outer_momentum <= 0.5`` caveat).  The
    fix: scale the momentum contribution by the measured delay,
    ``mu_eff = momentum / (1 + delay)`` — at delay 0 this is bit-equal
    to :func:`nesterov_outer`, at the async policy's steady-state delay
    of one round it lands 0.9 at 0.45, back inside the stable band.

    ``update`` takes an extra ``delay`` keyword (f32 scalar, number of
    rounds folded between the pseudo-gradient's snapshot and its
    application); the cluster runtime measures and threads it through.
    """

    def init(params):
        return {"m": _zeros_like_f32(params)}

    def update(grads, state, params=None, delay=0.0):
        mu = momentum / (1.0 + delay)
        m = jax.tree.map(lambda m_, g: mu * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        upd = jax.tree.map(
            lambda m_, g: -lr * (mu * m_ + g.astype(jnp.float32)),
            m, grads)
        return upd, {"m": m}

    return Optimizer(init, update)


# ------------------------------------------------------------------
# AdamW — the inner optimizer
# ------------------------------------------------------------------

def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": _zeros_like_f32(params),
            "v": _zeros_like_f32(params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        mhat_scale = 1.0 / (1 - jnp.power(b1, tf))
        vhat_scale = 1.0 / (1 - jnp.power(b2, tf))

        def upd(m_, v_, p):
            step = m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


# ------------------------------------------------------------------
# AdaGrad (AdAdaGrad's base adaptive method)
# ------------------------------------------------------------------

def adagrad(lr: float, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return {"acc": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        acc = jax.tree.map(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                           state["acc"], grads)
        updates = jax.tree.map(
            lambda a, g: -lr * g.astype(jnp.float32) / (jnp.sqrt(a) + eps),
            acc, grads)
        return updates, {"acc": acc}

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "adagrad": adagrad,
            "nesterov": nesterov_outer,
            "delay_nesterov": delay_compensated_nesterov}[name](lr, **kw)
