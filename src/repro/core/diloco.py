"""DiLoCo primitives: jitted inner step (with SwitchMode gradient
accumulation) and outer step (Nesterov on averaged pseudo-gradients).

These are the device-side building blocks; orchestration (trainer pool,
merging, batch adaptation) lives in ``adloco.py``.  A ``StepCache``
memoizes compiled steps per (micro_batch, accum_steps) bucket so adaptive
batching doesn't thrash XLA.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.switch import ExecutionPlan


def make_inner_step_fn(loss_fn: Callable, inner_opt: optim.Optimizer,
                       accum_steps: int):
    """Unjitted inner-step builder (the launcher jits it with explicit
    shardings/donation; ``make_inner_step`` jits it for host use).

    fn(params, opt_state, batch) -> (params, opt_state, loss, grads).
    ``batch`` leaves are shaped (accum_steps, micro, ...); accumulation is
    a ``lax.scan`` so the HLO stays O(1) in accum_steps (SwitchMode's
    device-side face).  The returned ``grads`` is the mean gradient the
    update used — reused by the distributed batching-stats estimator.
    For accum_steps == 1 the f32 accumulation buffer is skipped (grads
    stay in param dtype — matters for the 314B configs' memory budget).
    """

    def step_noaccum(params, opt_state, batch):
        mb = jax.tree.map(lambda x: x[0], batch)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mb)
        updates, opt_state = inner_opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss, grads

    def step(params, opt_state, batch):
        def micro_grad(carry, mb):
            g_acc, l_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_sum, l_sum), _ = jax.lax.scan(micro_grad, (g0, jnp.float32(0.0)),
                                         batch)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, g_sum)
        updates, opt_state = inner_opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, l_sum * inv, grads

    return step_noaccum if accum_steps == 1 else step


def make_inner_step(loss_fn: Callable, inner_opt: optim.Optimizer,
                    accum_steps: int):
    # NOTE: no donation — the orchestrator reuses x_start across the M
    # workers and the outer step (the distributed launch path in
    # repro/launch/train.py donates instead).
    return jax.jit(make_inner_step_fn(loss_fn, inner_opt, accum_steps))


def make_outer_step(outer_opt: optim.Optimizer, *,
                    delay_aware: bool = False):
    """jitted fn(x_prev, worker_params [stacked leading M axis],
    outer_state, delay) -> (x_new, outer_state).

    Pseudo-gradient Δ = x_prev − mean_m(x_m)  (paper Alg 3 line 42); in a
    multi-host deployment the mean is the inter-worker all-reduce this
    framework meters as communication.  ``delay`` is the measured
    staleness (rounds folded between snapshot and application, f32
    scalar): with ``delay_aware=True`` it is forwarded to the
    optimizer's ``update`` (``optim.delay_compensated_nesterov``), which
    scales the momentum contribution accordingly; otherwise it is
    ignored, keeping the plain path bit-identical to the legacy step.
    """

    def step(x_prev, worker_params, outer_state, delay=0.0):
        delta = jax.tree.map(
            lambda xp, w: xp.astype(jnp.float32)
            - jnp.mean(w.astype(jnp.float32), axis=0),
            x_prev, worker_params)
        if delay_aware:
            updates, outer_state = outer_opt.update(
                delta, outer_state, x_prev, delay=delay)
        else:
            updates, outer_state = outer_opt.update(delta, outer_state,
                                                    x_prev)
        x_new = optim.apply_updates(x_prev, updates)
        return x_new, outer_state

    return jax.jit(step)


def merge_params(params_list, weights):
    """Batch-size-weighted parameter average (paper Alg 2, DoMerge)."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)
    return jax.tree.map(
        lambda s: jnp.tensordot(w, s.astype(jnp.float32), axes=1).astype(s.dtype),
        stacked)


class StepCache:
    """Compiled inner steps keyed by (micro_batch, accum_steps)."""

    def __init__(self, loss_fn: Callable, inner_opt: optim.Optimizer):
        self.loss_fn = loss_fn
        self.inner_opt = inner_opt
        self._cache: Dict[Tuple[int, int], Callable] = {}

    def get(self, plan: ExecutionPlan):
        key = (plan.micro_batch, plan.accum_steps)
        if key not in self._cache:
            self._cache[key] = make_inner_step(
                self.loss_fn, self.inner_opt, plan.accum_steps)
        return self._cache[key]

    @property
    def num_compiled(self) -> int:
        return len(self._cache)


def reshape_for_plan(batch, plan: ExecutionPlan):
    """Leaves (plan.effective_batch, ...) -> (accum, micro, ...)."""
    return jax.tree.map(
        lambda x: x.reshape(plan.accum_steps, plan.micro_batch, *x.shape[1:]),
        batch)
