"""LocalSGD baseline (Stich 2019, paper §3.1): M workers do independent
SGD steps, parameters are plain-averaged every H steps (eq 5).  Also
provides the vanilla-DiLoCo baseline configuration helper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import AdLoCoConfig
from repro.core.adloco import History, train_adloco
from repro.core.comms import CommsMeter, param_bytes
from repro.core.diloco import StepCache, reshape_for_plan
from repro.core.switch import plan_execution


def train_local_sgd(loss_fn: Callable, init_params: Any, streams: List[Any],
                    *, num_rounds: int, inner_steps: int, lr: float,
                    batch_size: int, verbose: bool = False):
    """eq 5: H local SGD steps then parameter averaging, repeated."""
    M = len(streams)
    opt = optim.sgd(lr)
    cache = StepCache(loss_fn, opt)
    plan = plan_execution(batch_size, batch_size, 10 ** 9)
    step_fn = cache.get(plan)
    comms = CommsMeter()
    hist = History()
    params = init_params
    opt_states = [opt.init(params) for _ in range(M)]
    samples = 0
    t0 = time.time()

    @jax.jit
    def average(stacked):
        return jax.tree.map(lambda w: jnp.mean(w.astype(jnp.float32),
                                               axis=0).astype(w.dtype),
                            stacked)

    for r in range(1, num_rounds + 1):
        worker_params, losses = [], []
        for m in range(M):
            wp = params
            for h in range(inner_steps):
                batch = streams[m].next_batch(batch_size)
                batch = reshape_for_plan(batch, plan)
                wp, opt_states[m], loss, _ = step_fn(wp, opt_states[m], batch)
                samples += batch_size
            worker_params.append(wp)
            losses.append(float(loss))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *worker_params)
        params = average(stacked)
        comms.record("avg", participants=M,
                     payload_bytes=param_bytes(params), step=r)
        hist.outer_step.append(r)
        hist.loss.append(sum(losses) / len(losses))
        hist.pool_size.append(1)
        hist.requested_batches.append([batch_size])
        hist.comm_events.append(comms.events)
        hist.comm_bytes.append(comms.total_bytes)
        hist.samples.append(samples)
        hist.wall.append(time.time() - t0)
        if verbose:
            print(f"[localsgd] r={r} loss={hist.loss[-1]:.4f}")
    return params, hist


def diloco_config(acfg: AdLoCoConfig, fixed_batch: int) -> AdLoCoConfig:
    """Vanilla DiLoCo = AdLoCo with adaptivity/merging/switching off and a
    single trainer of M workers at a fixed batch size."""
    return dataclasses.replace(
        acfg, adaptive=False, enable_merge=False, enable_switch=False,
        num_init_trainers=1, initial_batch_size=fixed_batch)


def train_diloco(loss_fn: Callable, init_params: Any, streams: List[Any],
                 acfg: AdLoCoConfig, *, fixed_batch: int,
                 num_outer_steps: Optional[int] = None, verbose: bool = False,
                 eval_fn: Optional[Callable] = None):
    cfg = diloco_config(acfg, fixed_batch)
    return train_adloco(loss_fn, [init_params], streams, cfg,
                        num_outer_steps=num_outer_steps, eval_fn=eval_fn,
                        fixed_batch=fixed_batch, verbose=verbose)
