"""Multi-Instance Training (paper §4.1): trainer pool, CheckMerge
(Algorithm 1) and DoMerge (Algorithm 2).

``do_merge`` and ``consolidate`` optionally take a ``reduce`` callable
supplied by a :class:`~repro.cluster.backend.CollectiveBackend` — when
present, the weighted average is computed by a real cross-group
collective (every process participates, members contribute their own
trainer's replica) instead of the in-process ``merge_params``.  The
callable sees ``reduce(trainers, weights, *, kind, tid)`` and must
return the merged parameter tree, replicated identically on every rank.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax

from repro.core.comms import CommsMeter, param_bytes
from repro.core.diloco import merge_params

MergeReduce = Callable[..., Any]


@dataclass
class TrainerState:
    """One trainer instance T_i (may span multiple workers/GPUs)."""

    tid: int
    params: Any                           # x_{T_i}
    outer_opt_state: Any
    inner_opt_states: List[Any]           # one per worker m in M
    requested_batch: int = 1              # b_i^req
    streams: List[Any] = field(default_factory=list)   # per-worker data


@dataclass
class TrainerPoolState:
    trainers: List[TrainerState]
    comms: CommsMeter = field(default_factory=CommsMeter)
    global_params: Any = None             # final consolidated model
    outer_opt_state: Any = None

    @property
    def k(self) -> int:
        return len(self.trainers)


def check_merge(requested_batches: List[int], w: int) -> List[int]:
    """Algorithm 1: indices of the w trainers with the smallest requested
    batch (proxy for least-advanced optimization).  Empty when w == 0 or
    k <= 1; w is clamped to k, so w >= k merges the whole pool."""
    k = len(requested_batches)
    if w == 0 or k <= 1:
        return []
    w = min(w, k)
    order = sorted(range(k), key=lambda i: (requested_batches[i], i))
    return order[:w]


def do_merge(pool: TrainerPoolState, merge_ids: List[int], step: int,
             *, reduce: Optional[MergeReduce] = None) -> TrainerPoolState:
    """Algorithm 2: weighted average of the merge set, keep the
    representative with the largest requested batch, carry its optimizer
    state forward; pool contracts by |S| − 1."""
    if len(merge_ids) <= 1:
        return pool
    S = [pool.trainers[i] for i in merge_ids]
    weights = [max(t.requested_batch, 1) for t in S]
    rep = max(S, key=lambda t: (t.requested_batch, -t.tid))
    if reduce is not None:
        merged = reduce(S, weights, kind="merge", tid=rep.tid)
    else:
        merged = merge_params([t.params for t in S], weights)
    rep.params = merged
    # representative inherits the *union* of data shards so merged
    # knowledge keeps training on all of it
    for t in S:
        if t is not rep:
            rep.streams.extend(t.streams)
    survivors = [t for i, t in enumerate(pool.trainers)
                 if i not in set(merge_ids) or t is rep]
    pool.comms.record("merge", participants=len(S),
                      payload_bytes=param_bytes(rep.params), step=step)
    pool.trainers = survivors
    return pool


def consolidate(pool: TrainerPoolState, step: int,
                *, reduce: Optional[MergeReduce] = None):
    """Final model: batch-size-weighted merge of all surviving trainers.

    With a backend ``reduce``, the collective runs even for a pool of
    one: on a multi-group backend only the surviving trainer's own
    group holds its live replica, so the "average" doubles as the
    broadcast that re-replicates the final model on every rank.  The
    comms meter still only records a consolidate for k > 1, matching
    the analytic simulator (a single-trainer consolidate is free).
    """
    weights = [max(t.requested_batch, 1) for t in pool.trainers]
    if reduce is not None:
        pool.global_params = reduce(pool.trainers, weights,
                                    kind="consolidate",
                                    tid=pool.trainers[0].tid)
    elif pool.k == 1:
        pool.global_params = pool.trainers[0].params
        return pool
    else:
        pool.global_params = merge_params(
            [t.params for t in pool.trainers], weights)
    if pool.k > 1:
        pool.comms.record("consolidate", participants=pool.k,
                          payload_bytes=param_bytes(pool.global_params),
                          step=step)
    return pool
