"""Multi-Instance Training (paper §4.1): trainer pool, CheckMerge
(Algorithm 1) and DoMerge (Algorithm 2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax

from repro.core.comms import CommsMeter, param_bytes
from repro.core.diloco import merge_params


@dataclass
class TrainerState:
    """One trainer instance T_i (may span multiple workers/GPUs)."""

    tid: int
    params: Any                           # x_{T_i}
    outer_opt_state: Any
    inner_opt_states: List[Any]           # one per worker m in M
    requested_batch: int = 1              # b_i^req
    streams: List[Any] = field(default_factory=list)   # per-worker data


@dataclass
class TrainerPoolState:
    trainers: List[TrainerState]
    comms: CommsMeter = field(default_factory=CommsMeter)
    global_params: Any = None             # final consolidated model
    outer_opt_state: Any = None

    @property
    def k(self) -> int:
        return len(self.trainers)


def check_merge(requested_batches: List[int], w: int) -> List[int]:
    """Algorithm 1: indices of the w trainers with the smallest requested
    batch (proxy for least-advanced optimization).  Empty when w == 0,
    k <= 1, or w > k."""
    k = len(requested_batches)
    if w == 0 or k <= 1:
        return []
    if w > k:
        return []
    order = sorted(range(k), key=lambda i: (requested_batches[i], i))
    return order[:w]


def do_merge(pool: TrainerPoolState, merge_ids: List[int], step: int
             ) -> TrainerPoolState:
    """Algorithm 2: weighted average of the merge set, keep the
    representative with the largest requested batch, carry its optimizer
    state forward; pool contracts by |S| − 1."""
    if len(merge_ids) <= 1:
        return pool
    S = [pool.trainers[i] for i in merge_ids]
    weights = [max(t.requested_batch, 1) for t in S]
    merged = merge_params([t.params for t in S], weights)
    rep = max(S, key=lambda t: (t.requested_batch, -t.tid))
    rep.params = merged
    # representative inherits the *union* of data shards so merged
    # knowledge keeps training on all of it
    for t in S:
        if t is not rep:
            rep.streams.extend(t.streams)
    survivors = [t for i, t in enumerate(pool.trainers)
                 if i not in set(merge_ids) or t is rep]
    pool.comms.record("merge", participants=len(S),
                      payload_bytes=param_bytes(rep.params), step=step)
    pool.trainers = survivors
    return pool


def consolidate(pool: TrainerPoolState, step: int):
    """Final model: batch-size-weighted merge of all surviving trainers."""
    if pool.k == 1:
        pool.global_params = pool.trainers[0].params
        return pool
    weights = [max(t.requested_batch, 1) for t in pool.trainers]
    pool.global_params = merge_params(
        [t.params for t in pool.trainers], weights)
    pool.comms.record("consolidate", participants=pool.k,
                      payload_bytes=param_bytes(pool.global_params), step=step)
    return pool
