"""AdLoCo core: the paper's contribution.

  batching   — adaptive batch-size tests (norm / inner-product / augmented)
  diloco     — jitted inner/outer step primitives
  mit        — trainer pool, CheckMerge / DoMerge
  switch     — SwitchMode execution planning
  adloco     — Algorithm 3 orchestrator
  local_sgd  — LocalSGD + vanilla-DiLoCo baselines
  comms      — communication metering (Theorem 2's C(N))
"""
from repro.core import batching, comms, diloco, local_sgd, mit, switch
from repro.core.adloco import (BatchPlanProtocol, History, RoundOutput,
                               TrainerRound, train_adloco)
from repro.core.local_sgd import diloco_config, train_diloco, train_local_sgd

__all__ = [
    "batching", "comms", "diloco", "local_sgd", "mit", "switch",
    "BatchPlanProtocol", "History", "RoundOutput", "TrainerRound",
    "train_adloco", "train_diloco", "train_local_sgd", "diloco_config",
]
