"""SwitchMode (paper §4.2): gradient accumulation only once the requested
batch exceeds n × max_batch; in the band (max_batch, n·max_batch] keep
plain capped steps to avoid early-accumulation variance.
"""
from __future__ import annotations

import math
from typing import NamedTuple


class ExecutionPlan(NamedTuple):
    micro_batch: int        # per-step device batch
    accum_steps: int        # sequential accumulation steps
    mode: str               # "plain" | "accum"

    @property
    def effective_batch(self) -> int:
        return self.micro_batch * self.accum_steps


def plan_execution(b_req: int, max_batch: int, switch_multiplier: int,
                   *, bucket: bool = True) -> ExecutionPlan:
    """Paper Algorithm 3 lines 17–27.

    ``bucket``: round micro_batch up to a power of two and accum_steps to
    a power of two so the number of distinct jit signatures stays
    logarithmic (beyond-paper engineering for XLA shape stability).
    """
    b_req = max(1, int(b_req))
    if b_req > switch_multiplier * max_batch:
        accum = math.ceil(b_req / max_batch)
        if bucket:
            accum = 1 << (accum - 1).bit_length()
        return ExecutionPlan(max_batch, accum, "accum")
    micro = min(b_req, max_batch)
    if bucket:
        micro = min(1 << (micro - 1).bit_length(), max_batch)
    return ExecutionPlan(micro, 1, "plain")
