"""SwitchMode (paper §4.2): gradient accumulation only once the requested
batch exceeds n × max_batch; in the band (max_batch, n·max_batch] keep
plain capped steps to avoid early-accumulation variance.
"""
from __future__ import annotations

import math
from typing import NamedTuple


class ExecutionPlan(NamedTuple):
    micro_batch: int        # per-step device batch
    accum_steps: int        # sequential accumulation steps
    mode: str               # "plain" | "accum"

    @property
    def effective_batch(self) -> int:
        return self.micro_batch * self.accum_steps


def plan_execution(b_req: int, max_batch: int, switch_multiplier: int,
                   *, bucket: bool = True) -> ExecutionPlan:
    """Paper Algorithm 3 lines 17–27.

    ``bucket``: round micro_batch up to a power of two and accum_steps to
    a power of two so the number of distinct jit signatures stays
    logarithmic (beyond-paper engineering for XLA shape stability).

    Invariant (pinned by the regression suite): the plan never consumes
    more than twice the requested batch — ``effective_batch <= 2·b_req``.
    With the current rounding this holds arithmetically: in the accum
    branch ``a = ceil(b/m) >= 2``, ``pow2(a) <= 2(a-1)`` and
    ``m·(a-1) < b``, so ``m·pow2(a) < 2b`` — though right at the switch
    boundary (b_req = n·max + 1) it lands *just* under the bound.  The
    guard below is therefore provably unreachable today; it exists so
    the bound is structural rather than an accident of that arithmetic:
    a future rounding change (e.g. bucketing the micro batch in accum
    mode too, where the factors would compound) degrades to the exact
    accum count — which always satisfies ``b_req <= m·a < b_req + m <=
    2·b_req`` — instead of silently overshooting.
    """
    b_req = max(1, int(b_req))
    if b_req > switch_multiplier * max_batch:
        accum = math.ceil(b_req / max_batch)
        if bucket:
            bucketed = 1 << (accum - 1).bit_length()
            if max_batch * bucketed <= 2 * b_req:
                accum = bucketed
        return ExecutionPlan(max_batch, accum, "accum")
    micro = min(b_req, max_batch)
    if bucket:
        micro = min(1 << (micro - 1).bit_length(), max_batch)
    return ExecutionPlan(micro, 1, "plain")
