"""Adaptive batch-size tests (AdAdaGrad family — paper §3.3 / eqs 10,12,13).

All three tests reduce to three statistics over per-sample gradients g_i
(i = 1..b) with mean ḡ:

  s_i = ||g_i||²,   d_i = <g_i, ḡ>,   n2 = ||ḡ||²

  norm test       σ² = (Σ s_i − b·n2) / (b−1)
                  b⁺ = ceil( σ² / (η² n2) )                       (eq 10)
  inner-product   v  = Σ (d_i − n2)² / (b−1)
                  b⁺ = ceil( v / (ϑ² n2²) )                       (eq 12)
  augmented       o  = Σ (s_i − d_i²/n2) / (b−1)
                  b⁺ = max(ipt, ceil( o / (ν² n2) ))              (eq 13)

(The orthogonal residuals have mean 0 because mean(g_i) = ḡ, so the
augmented variance is the mean squared residual norm.)

Two estimator paths for the statistics:
  * exact per-sample grads (vmap-of-grad) — small models, tests;
  * distributed microbatch estimator: with per-replica microbatch-mean
    grads G_j over m samples each, Var(G_j) = σ²/m, so σ² = m·Var(G_j) —
    statistics data parallelism already materializes for free.

The fused single-pass reduction over the (B, D) gradient matrix is the
``gradstats`` Pallas kernel; ``repro.kernels.gradstats.ref`` is the
pure-jnp oracle used here by default.

Distributed composition (the shape-agreement protocol)
------------------------------------------------------
When the per-sample (or per-microbatch-mean) gradient rows live on
different processes, the statistics still compose *exactly*: given the
global mean direction ḡ, every test above is a function of five
additive reductions over the rows —

  (b,  Σ‖g_i‖²,  Σ<g_i, ḡ>,  Σ<g_i, ḡ>²,  b·‖ḡ‖²)

— and sums and counts all-reduce trivially.  :func:`distributed_stats`
runs the two-phase protocol: (1) all-reduce the column sum and row
count to obtain ḡ, (2) compute the local :func:`shard_moments` against
ḡ and all-reduce the five scalars.  The result equals
:func:`stats_from_matrix` on the row-concatenation of every shard (to
float-associativity tolerance), including the degenerate one-row-per-
shard case the distributed microbatch estimator produces — which is
what lets every rank derive the identical batch decision from the
identical reduced statistics (see ``repro.core.adloco.
BatchPlanProtocol``).
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp


class GradStats(NamedTuple):
    """Sufficient statistics for all batching tests (f32 scalars)."""
    mean_norm2: jnp.ndarray     # ||ḡ||²
    sigma2: jnp.ndarray         # trace-variance of per-sample grads
    ip_var: jnp.ndarray         # Var(<g_i, ḡ>)
    orth_var: jnp.ndarray       # Var of orthogonal residuals
    b: jnp.ndarray              # number of samples the stats came from


def stats_from_matrix(G: jnp.ndarray, *, use_kernel: bool = False) -> GradStats:
    """G: (B, D) per-sample (or per-microbatch-mean) flattened gradients."""
    if use_kernel:
        from repro.kernels.gradstats.ops import gradstats_reduce
        s, d, gbar_n2, b = gradstats_reduce(G)
    else:
        from repro.kernels.gradstats.ref import gradstats_reduce_ref
        s, d, gbar_n2, b = gradstats_reduce_ref(G)
    bm1 = jnp.maximum(b - 1.0, 1.0)
    sigma2 = (jnp.sum(s) - b * gbar_n2) / bm1
    ip_var = jnp.sum(jnp.square(d - gbar_n2)) / bm1
    orth_var = (jnp.sum(s) - jnp.sum(jnp.square(d)) /
                jnp.maximum(gbar_n2, 1e-30)) / bm1
    return GradStats(gbar_n2, jnp.maximum(sigma2, 0.0),
                     jnp.maximum(ip_var, 0.0), jnp.maximum(orth_var, 0.0), b)


def stats_from_microbatch_grads(grads_stack, micro_size: int, *,
                                use_kernel: bool = False) -> GradStats:
    """grads_stack: pytree with leading axis J of per-microbatch mean
    grads (each over ``micro_size`` samples).  Rescales the variance
    estimates to per-sample units: Var(G_j) = σ²/m  =>  σ² = m·Var."""
    G = flatten_grads(grads_stack)
    st = stats_from_matrix(G, use_kernel=use_kernel)
    return rescale_microbatch(st, micro_size)


def rescale_microbatch(st: GradStats, micro_size: int) -> GradStats:
    """Microbatch-mean rows to per-sample units (σ² = m·Var(G_j))."""
    m = jnp.float32(micro_size)
    return GradStats(st.mean_norm2, st.sigma2 * m, st.ip_var * m,
                     st.orth_var * m, st.b)


# ------------------------------------------------------------------
# distributed composition: additive sufficient statistics
# ------------------------------------------------------------------

def shard_moments(G: jnp.ndarray, gbar: jnp.ndarray) -> jnp.ndarray:
    """The five additive sufficient statistics of shard ``G`` against
    the *global* mean direction ``gbar``, packed as an f32 ``(5,)``
    vector ``[b, Σ‖g_i‖², Σ<g_i,ḡ>, Σ<g_i,ḡ>², b·‖ḡ‖²]``.

    Summing these vectors over disjoint shards yields the exact global
    reductions (every entry is a sum over rows, or the row count times
    the shared ``‖ḡ‖²``), so :func:`stats_from_moments` of the sum
    equals :func:`stats_from_matrix` of the row concatenation.
    """
    G = G.astype(jnp.float32)
    gbar = gbar.astype(jnp.float32)
    b = jnp.float32(G.shape[0])
    s = jnp.sum(jnp.square(G), axis=1)
    d = G @ gbar
    n2 = jnp.sum(jnp.square(gbar))
    return jnp.stack([b, jnp.sum(s), jnp.sum(d),
                      jnp.sum(jnp.square(d)), b * n2])


def stats_from_moments(m: jnp.ndarray) -> GradStats:
    """GradStats from summed :func:`shard_moments` (the inverse of the
    additive encoding; same guards as :func:`stats_from_matrix`)."""
    b, sum_s, sum_d, sum_d2, b_n2 = m[0], m[1], m[2], m[3], m[4]
    n2 = b_n2 / jnp.maximum(b, 1.0)
    bm1 = jnp.maximum(b - 1.0, 1.0)
    sigma2 = (sum_s - b * n2) / bm1
    ip_var = (sum_d2 - 2.0 * n2 * sum_d + b * jnp.square(n2)) / bm1
    orth_var = (sum_s - sum_d2 / jnp.maximum(n2, 1e-30)) / bm1
    return GradStats(n2, jnp.maximum(sigma2, 0.0),
                     jnp.maximum(ip_var, 0.0),
                     jnp.maximum(orth_var, 0.0), b)


def stats_phase1(G_local: jnp.ndarray) -> jnp.ndarray:
    """Phase-1 payload of the two-phase composition: the ``[colsum, b]``
    f32 vector whose SUM all-reduce yields the global mean direction.
    Split out of :func:`distributed_stats` so the runtime can dispatch
    the reduction nonblocking (piggybacked on the outer sync) and finish
    the statistics later with :func:`stats_finish`."""
    G_local = G_local.astype(jnp.float32)
    b_local = jnp.full((1,), G_local.shape[0], jnp.float32)
    return jnp.concatenate([jnp.sum(G_local, axis=0), b_local])


def stats_finish(tot: jnp.ndarray, G_local: jnp.ndarray,
                 sum_reduce: Callable, *, micro_size: int = 0) -> GradStats:
    """Finish the two-phase composition given the already-reduced
    phase-1 total ``tot`` (= sum of every shard's :func:`stats_phase1`):
    derive ḡ, reduce the five :func:`shard_moments` (phase 2), and
    rescale.  Bit-identical to the inline :func:`distributed_stats`."""
    G_local = G_local.astype(jnp.float32)
    gbar = tot[:-1] / jnp.maximum(tot[-1], 1.0)
    st = stats_from_moments(sum_reduce(shard_moments(G_local, gbar)))
    return rescale_microbatch(st, micro_size) if micro_size else st


def stats_finish_total(moments_total: jnp.ndarray, *,
                       micro_size: int = 0) -> GradStats:
    """Finish from an already-reduced phase-2 moments total (= sum of
    every shard's :func:`shard_moments`), for backends that fuse the
    phase-2 reduction onto another in-flight collective and hand the
    runtime the summed vector directly.  Bit-identical to
    :func:`stats_finish` fed the same reduction."""
    st = stats_from_moments(jnp.asarray(moments_total, jnp.float32))
    return rescale_microbatch(st, micro_size) if micro_size else st


def distributed_stats(G_local: jnp.ndarray, sum_reduce: Callable, *,
                      micro_size: int = 0) -> GradStats:
    """Two-phase exact composition of :class:`GradStats` across shards.

    ``G_local`` is this process's ``(b_local, D)`` shard of gradient
    rows; ``sum_reduce`` is an elementwise SUM all-reduce of a small
    1-D f32 vector over every participating process (identity on a
    single process).  Phase 1 reduces ``[colsum, b]`` so every rank
    holds the global mean ḡ; phase 2 reduces the five
    :func:`shard_moments`.  Both phases are deterministic collectives,
    so every rank returns bit-identical statistics — the agreement the
    batch-plan protocol builds on.  ``micro_size`` > 0 applies the
    microbatch-estimator rescale to per-sample units.
    """
    return stats_finish(sum_reduce(stats_phase1(G_local)), G_local,
                        sum_reduce, micro_size=micro_size)


def compose_shards(shards: Sequence[jnp.ndarray], *,
                   micro_size: int = 0) -> GradStats:
    """In-process reference of the distributed protocol: run the exact
    two-phase composition over a list of shards (as if each lived on
    its own process).  Property-tested against
    ``stats_from_matrix(concat(shards))``."""
    phase1s = [jnp.concatenate([jnp.sum(G.astype(jnp.float32), axis=0),
                                jnp.full((1,), G.shape[0], jnp.float32)])
               for G in shards]
    tot = sum(phase1s[1:], start=phase1s[0])
    gbar = tot[:-1] / jnp.maximum(tot[-1], 1.0)
    moments = [shard_moments(G, gbar) for G in shards]
    st = stats_from_moments(sum(moments[1:], start=moments[0]))
    return rescale_microbatch(st, micro_size) if micro_size else st


def stats_payload_bytes(n_params: int) -> float:
    """Wire payload of one stats reduction: the phase-1 ``[colsum, b]``
    f32 vector plus the five phase-2 moments — what the cluster runtime
    prices the collective at.  Note the phase-1 vector is one f32 per
    parameter, i.e. the same order as a gradient all-reduce: the
    protocol is exact, not cheap.  Under the async policy the runtime
    therefore piggybacks this payload onto the outer sync (one fused
    ``"piggyback"`` collective priced at params + stats bytes) instead
    of paying a second gradient-order all-reduce; sync keeps the
    standalone reduction so it stays bit-identical to the host loop."""
    return 4.0 * (n_params + 1 + 5)


def flatten_grads(tree) -> jnp.ndarray:
    """Pytree with leading axis B -> (B, D) f32 matrix."""
    leaves = jax.tree.leaves(tree)
    B = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(B, -1).astype(jnp.float32) for l in leaves], axis=1)


def per_sample_stats(loss_fn, params, batch, *, use_kernel: bool = False
                     ) -> GradStats:
    """Exact path: vmap of grad over the batch's sample axis."""
    def one(sample):
        sb = jax.tree.map(lambda x: x[None], sample)
        return jax.grad(lambda p: loss_fn(p, sb)[0])(params)

    per = jax.vmap(one)(batch)
    return stats_from_matrix(flatten_grads(per), use_kernel=use_kernel)


# ------------------------------------------------------------------
# the batch-size tests
# ------------------------------------------------------------------

def _ceil_robust(x: jnp.ndarray) -> jnp.ndarray:
    """``ceil`` with a 1e-6 relative guard band below each integer.

    The batch decision must agree across numerically different routes
    to the same statistics (in-process ``stats_from_matrix`` vs the
    two-phase ``distributed_stats`` composition differ by f32
    re-association, ~1e-7 relative).  A bare ceil flips by one whenever
    the exact ratio lands on an integer and the routes straddle it —
    which deterministic fixtures actually do — so the sim/real parity
    gates would be flaky by construction.  Shrinking x by 1e-6 relative
    before the ceil absorbs ulp-scale noise around integer ratios
    (exactly-integer x keeps its value; the flip set moves to the
    measure-1e-6 band above each integer, which noisy statistics hit
    with negligible probability)."""
    return jnp.ceil(x * (1.0 - 1e-6))


def norm_test(st: GradStats, eta: float) -> jnp.ndarray:
    """eq 10.  Returns requested batch (f32, >= 1)."""
    return _ceil_robust(
        st.sigma2 / (eta ** 2 * jnp.maximum(st.mean_norm2, 1e-30)))


def inner_product_test(st: GradStats, theta: float) -> jnp.ndarray:
    """eq 12."""
    return _ceil_robust(
        st.ip_var / (theta ** 2 * jnp.maximum(st.mean_norm2, 1e-30) ** 2))


def augmented_test(st: GradStats, theta: float, nu: float) -> jnp.ndarray:
    """eq 13: max of the inner-product test and the orthogonality test."""
    b_ipt = inner_product_test(st, theta)
    b_orth = _ceil_robust(st.orth_var /
                          (nu ** 2 * jnp.maximum(st.mean_norm2, 1e-30)))
    return jnp.maximum(b_ipt, b_orth)


def requested_batch(st: GradStats, acfg, current_b: int) -> int:
    """Apply the configured test; enforce monotone growth (paper Lemma 1:
    b_{k+1} >= b_k) and the global cap."""
    if acfg.batch_test == "norm":
        b = norm_test(st, acfg.eta)
    elif acfg.batch_test == "inner_product":
        b = inner_product_test(st, acfg.theta)
    elif acfg.batch_test == "augmented":
        b = augmented_test(st, acfg.theta, acfg.nu)
    else:
        raise ValueError(acfg.batch_test)
    b = int(jax.device_get(b))
    b = max(b, int(current_b))          # monotone non-decreasing
    return int(min(b, acfg.max_global_batch))


# ------------------------------------------------------------------
# predicted batch growth (PadaDamp; Lau et al., arXiv 2406.13936)
# ------------------------------------------------------------------

class BatchGrowthPredictor:
    """Fit the observed batch-growth trajectory and predict between
    exact estimates.

    The adaptive tests above make the requested batch track the falling
    gradient signal-to-noise ratio, which under geometric loss decay is
    (close to) exponential in the round index — so ``ln b`` is fit by
    least squares against the round number over the *exact* decisions
    observed so far, and skipped rounds read the fitted line instead of
    paying a gradient-order stats reduction (``acfg.k_correct``).

    Determinism contract: the fit is pure Python float arithmetic over
    observations that are identical on every rank by the shape-agreement
    protocol (exact decisions are reduced collectively), so every rank
    derives the identical predicted batch with **zero** collectives on
    non-correction rounds.  Predictions are conservative — the slope is
    clamped non-negative, the fitted value floored to an int, growth
    kept monotone and capped — so an over-eager fit cannot lock in a
    runaway batch between corrections (the cap and the monotone floor
    are the same policy the exact path applies).
    """

    def __init__(self, max_global_batch: int):
        self.max_global_batch = int(max_global_batch)
        self._rounds: list = []
        self._batches: list = []

    def observe(self, round_i: int, b: int) -> None:
        """Record an exact decision (correction round)."""
        round_i, b = int(round_i), int(b)
        if b < 1:
            return
        if self._rounds and round_i <= self._rounds[-1]:
            return                      # stale/duplicate fold (async)
        self._rounds.append(round_i)
        self._batches.append(b)

    @property
    def num_observations(self) -> int:
        return len(self._rounds)

    def predict(self, round_i: int, current_b: int) -> int:
        """Predicted batch for ``round_i``; falls back to ``current_b``
        until two exact observations anchor the fit."""
        if len(self._rounds) < 2:
            return int(current_b)
        xs, ys = self._rounds, [math.log(b) for b in self._batches]
        n = float(len(xs))
        mx = sum(xs) / n
        my = sum(ys) / n
        sxx = sum((x - mx) ** 2 for x in xs)
        sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
        slope = max(0.0, sxy / sxx) if sxx > 0.0 else 0.0
        b = int(math.floor(math.exp(my + slope * (round_i - mx)) + 1e-9))
        b = max(b, int(current_b))      # monotone non-decreasing
        return int(min(b, self.max_global_batch))
