"""Adaptive batch-size tests (AdAdaGrad family — paper §3.3 / eqs 10,12,13).

All three tests reduce to three statistics over per-sample gradients g_i
(i = 1..b) with mean ḡ:

  s_i = ||g_i||²,   d_i = <g_i, ḡ>,   n2 = ||ḡ||²

  norm test       σ² = (Σ s_i − b·n2) / (b−1)
                  b⁺ = ceil( σ² / (η² n2) )                       (eq 10)
  inner-product   v  = Σ (d_i − n2)² / (b−1)
                  b⁺ = ceil( v / (ϑ² n2²) )                       (eq 12)
  augmented       o  = Σ (s_i − d_i²/n2) / (b−1)
                  b⁺ = max(ipt, ceil( o / (ν² n2) ))              (eq 13)

(The orthogonal residuals have mean 0 because mean(g_i) = ḡ, so the
augmented variance is the mean squared residual norm.)

Two estimator paths for the statistics:
  * exact per-sample grads (vmap-of-grad) — small models, tests;
  * distributed microbatch estimator: with per-replica microbatch-mean
    grads G_j over m samples each, Var(G_j) = σ²/m, so σ² = m·Var(G_j) —
    statistics data parallelism already materializes for free.

The fused single-pass reduction over the (B, D) gradient matrix is the
``gradstats`` Pallas kernel; ``repro.kernels.gradstats.ref`` is the
pure-jnp oracle used here by default.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GradStats(NamedTuple):
    """Sufficient statistics for all batching tests (f32 scalars)."""
    mean_norm2: jnp.ndarray     # ||ḡ||²
    sigma2: jnp.ndarray         # trace-variance of per-sample grads
    ip_var: jnp.ndarray         # Var(<g_i, ḡ>)
    orth_var: jnp.ndarray       # Var of orthogonal residuals
    b: jnp.ndarray              # number of samples the stats came from


def stats_from_matrix(G: jnp.ndarray, *, use_kernel: bool = False) -> GradStats:
    """G: (B, D) per-sample (or per-microbatch-mean) flattened gradients."""
    if use_kernel:
        from repro.kernels.gradstats.ops import gradstats_reduce
        s, d, gbar_n2, b = gradstats_reduce(G)
    else:
        from repro.kernels.gradstats.ref import gradstats_reduce_ref
        s, d, gbar_n2, b = gradstats_reduce_ref(G)
    bm1 = jnp.maximum(b - 1.0, 1.0)
    sigma2 = (jnp.sum(s) - b * gbar_n2) / bm1
    ip_var = jnp.sum(jnp.square(d - gbar_n2)) / bm1
    orth_var = (jnp.sum(s) - jnp.sum(jnp.square(d)) /
                jnp.maximum(gbar_n2, 1e-30)) / bm1
    return GradStats(gbar_n2, jnp.maximum(sigma2, 0.0),
                     jnp.maximum(ip_var, 0.0), jnp.maximum(orth_var, 0.0), b)


def stats_from_microbatch_grads(grads_stack, micro_size: int) -> GradStats:
    """grads_stack: pytree with leading axis J of per-microbatch mean
    grads (each over ``micro_size`` samples).  Rescales the variance
    estimates to per-sample units: Var(G_j) = σ²/m  =>  σ² = m·Var."""
    G = flatten_grads(grads_stack)
    st = stats_from_matrix(G)
    m = jnp.float32(micro_size)
    return GradStats(st.mean_norm2, st.sigma2 * m, st.ip_var * m,
                     st.orth_var * m, st.b)


def flatten_grads(tree) -> jnp.ndarray:
    """Pytree with leading axis B -> (B, D) f32 matrix."""
    leaves = jax.tree.leaves(tree)
    B = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(B, -1).astype(jnp.float32) for l in leaves], axis=1)


def per_sample_stats(loss_fn, params, batch, *, use_kernel: bool = False
                     ) -> GradStats:
    """Exact path: vmap of grad over the batch's sample axis."""
    def one(sample):
        sb = jax.tree.map(lambda x: x[None], sample)
        return jax.grad(lambda p: loss_fn(p, sb)[0])(params)

    per = jax.vmap(one)(batch)
    return stats_from_matrix(flatten_grads(per), use_kernel=use_kernel)


# ------------------------------------------------------------------
# the batch-size tests
# ------------------------------------------------------------------

def norm_test(st: GradStats, eta: float) -> jnp.ndarray:
    """eq 10.  Returns requested batch (f32, >= 1)."""
    return jnp.ceil(st.sigma2 / (eta ** 2 * jnp.maximum(st.mean_norm2, 1e-30)))


def inner_product_test(st: GradStats, theta: float) -> jnp.ndarray:
    """eq 12."""
    return jnp.ceil(st.ip_var /
                    (theta ** 2 * jnp.maximum(st.mean_norm2, 1e-30) ** 2))


def augmented_test(st: GradStats, theta: float, nu: float) -> jnp.ndarray:
    """eq 13: max of the inner-product test and the orthogonality test."""
    b_ipt = inner_product_test(st, theta)
    b_orth = jnp.ceil(st.orth_var /
                      (nu ** 2 * jnp.maximum(st.mean_norm2, 1e-30)))
    return jnp.maximum(b_ipt, b_orth)


def requested_batch(st: GradStats, acfg, current_b: int) -> int:
    """Apply the configured test; enforce monotone growth (paper Lemma 1:
    b_{k+1} >= b_k) and the global cap."""
    if acfg.batch_test == "norm":
        b = norm_test(st, acfg.eta)
    elif acfg.batch_test == "inner_product":
        b = inner_product_test(st, acfg.theta)
    elif acfg.batch_test == "augmented":
        b = augmented_test(st, acfg.theta, acfg.nu)
    else:
        raise ValueError(acfg.batch_test)
    b = int(jax.device_get(b))
    b = max(b, int(current_b))          # monotone non-decreasing
    return int(min(b, acfg.max_global_batch))
