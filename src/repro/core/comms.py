"""Communication accounting — the quantity AdLoCo minimizes (Theorem 2).

Counts every inter-instance parameter exchange: DiLoCo outer syncs
(all-reduce of pseudo-gradients over a trainer's M workers), MIT merges
(weighted all-reduce over the merge set), and final consolidation.
Bytes use the ring all-reduce model: 2 (p−1)/p · payload per participant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple, Union

import jax
import numpy as np


def param_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))


@dataclass
class CommsMeter:
    events: int = 0                  # discrete sync events (paper's C(N))
    total_bytes: float = 0.0
    log: List[dict] = field(default_factory=list)

    def record(self, kind: str, participants: int, payload_bytes: int,
               step: int) -> None:
        p = max(participants, 1)
        ring = 2.0 * (p - 1) / p * payload_bytes * p   # total wire bytes
        self.events += 1
        self.total_bytes += ring
        self.log.append({"step": step, "kind": kind,
                         "participants": p, "bytes": ring})

    def snapshot(self) -> dict:
        return {"events": self.events, "bytes": self.total_bytes}


def ring_allreduce_time(payload_bytes: float, participants: int,
                        link_bw: float, latency: float = 0.0) -> float:
    """Latency + bandwidth cost of one ring all-reduce, in seconds.

    2(p−1) ring steps, each paying the per-hop latency; every node
    transmits 2(p−1)/p · payload bytes over its (slowest) link.  With
    p <= 1 there is nothing to exchange.  A non-positive bandwidth is a
    misconfiguration and fails loudly (there is no 1 byte/s floor to
    silently absorb it).
    """
    p = max(int(participants), 1)
    if p == 1 or payload_bytes <= 0:
        return 0.0
    if link_bw <= 0.0:
        raise ValueError(f"link_bw must be positive, got {link_bw}")
    steps = 2 * (p - 1)
    wire = 2.0 * (p - 1) / p * payload_bytes
    return steps * latency + wire / link_bw


@dataclass
class CommDomain:
    """One fabric domain in an n-level all-reduce hierarchy.

    A *leaf* domain is a group of ``size`` nodes whose links run at
    ``bw`` bytes/s with ``latency`` seconds per hop.  An *internal*
    domain joins its ``children`` with per-path bandwidth ``bw`` — one
    child's route to its peers at this level, not an aggregate pipe —
    and per-hop ``latency``.  Nesting domains to any depth describes a
    rack / pod / cluster style fabric; :func:`hierarchical_allreduce_time`
    prices an all-reduce over the tree by recursing over the levels.
    """

    bw: float
    latency: float = 0.0
    size: int = 0
    children: Tuple["CommDomain", ...] = ()

    def __post_init__(self) -> None:
        self.children = tuple(self.children)
        if self.children and self.size:
            raise ValueError("a CommDomain is either a leaf (size) or a "
                             "parent (children), not both")

    def leaves(self) -> int:
        """Total node count under this domain."""
        if not self.children:
            return max(int(self.size), 0)
        return sum(c.leaves() for c in self.children)


def _prune(d: CommDomain):
    """Drop empty groups and collapse single-child levels (a level with
    one participating child prices nothing — there is no ring to run)."""
    if not d.children:
        return d if d.size >= 1 else None
    kids = [k for k in (_prune(c) for c in d.children) if k is not None]
    if not kids:
        return None
    if len(kids) == 1:
        return kids[0]
    return CommDomain(bw=d.bw, latency=d.latency, children=tuple(kids))


def _check_bws(d: CommDomain) -> None:
    if not d.children:
        if d.size > 1 and d.bw <= 0.0:
            raise ValueError(f"leaf domain bandwidth must be positive, "
                             f"got {d.bw}")
        return
    if d.bw <= 0.0:
        raise ValueError(f"internal domain bandwidth must be positive, "
                         f"got {d.bw}")
    for c in d.children:
        _check_bws(c)


def _scatter(payload_bytes: float, d: CommDomain):
    """(reduce-scatter time down this subtree, shard capacity).

    After the subtree's reduce-scatter every node holds a shard no
    larger than ``payload / capacity``; unbalanced sibling groups leave
    the largest shard — ``payload / min(child capacities)`` — as the
    critical payload of the level above.  The all-gather back up is the
    mirror image and costs the same, which is why callers double it.
    """
    if not d.children:
        p = d.size
        if p <= 1:
            return 0.0, max(p, 1)
        return (p - 1) * d.latency + ((p - 1) / p * payload_bytes) / d.bw, p
    subs = [_scatter(payload_bytes, c) for c in d.children]
    down = max(t for t, _ in subs)
    cap = min(c for _, c in subs)
    k = len(d.children)
    here = (k - 1) * d.latency + ((k - 1) / k * (payload_bytes / cap)) / d.bw
    return down + here, k * cap


def _tree_allreduce_time(payload_bytes: float, root: CommDomain) -> float:
    d = _prune(root)
    if d is None or d.leaves() <= 1 or payload_bytes <= 0:
        return 0.0
    _check_bws(d)
    if not d.children:
        return ring_allreduce_time(payload_bytes, d.size, d.bw, d.latency)
    subs = [_scatter(payload_bytes, c) for c in d.children]
    down = max(t for t, _ in subs)
    shard = payload_bytes / min(c for _, c in subs)
    cross = ring_allreduce_time(shard, len(d.children), d.bw, d.latency)
    return 2.0 * down + cross


def _per_pod(value, pod_sizes: Sequence[int], what: str):
    try:
        vals = [float(v) for v in value]
    except TypeError:
        return [float(value)] * len(pod_sizes)
    if len(vals) != len(pod_sizes):
        raise ValueError(f"per-pod {what} needs {len(pod_sizes)} entries, "
                         f"got {len(vals)}")
    return vals


def hierarchical_allreduce_time(payload_bytes: float,
                                tree: Union[CommDomain, Sequence[int]],
                                intra_bw=None, inter_bw: float = None, *,
                                intra_latency=0.0,
                                inter_latency: float = 0.0) -> float:
    """N-level hierarchical all-reduce cost, in seconds.

    The schedule is a recursion over fabric levels: ring reduce-scatter
    inside every leaf group (siblings run in parallel; the slowest group
    is the critical path), then a reduce-scatter of the surviving shards
    across each internal level on the way up, a full shard ring across
    the top level's children, and the mirror-image all-gathers back
    down.  At every level ``bw`` is the bandwidth of one *path* (one
    child's route to its peers at that level), not an aggregate pipe:
    the per-node shard rings are concurrent, which is what makes the
    schedule collapse to the flat ring when upper levels match the node
    links.  The shard entering a level is ``payload / min(child
    capacities)`` — the smallest sibling sets the granularity, which is
    why a lopsided split can lose to a flat ring threaded through the
    same fabric (see :meth:`~repro.cluster.network.Topology.
    allreduce_time`, which routes via the cheaper of the two).

    ``tree`` is either a :class:`CommDomain` (arbitrary depth >= 1; a
    single leaf domain is priced *exactly* as
    :func:`ring_allreduce_time`) or, for the classic two-level pod
    scheme, a sequence of pod sizes with ``intra_bw``/``intra_latency``
    as single values or per-pod sequences and ``inter_bw``/
    ``inter_latency`` for the cross-pod paths.  The two spellings agree
    bit-for-bit at depth 2.
    """
    if isinstance(tree, CommDomain):
        if intra_bw is not None or inter_bw is not None:
            raise ValueError("pass bandwidths inside the CommDomain tree, "
                             "not as separate arguments")
        return _tree_allreduce_time(payload_bytes, tree)
    pod_sizes = tree
    if intra_bw is None:
        raise ValueError("intra_bw is required with the pod-sizes "
                         "spelling (or pass a CommDomain tree)")
    bws = _per_pod(intra_bw, pod_sizes, "intra_bw")
    lats = _per_pod(intra_latency, pod_sizes, "intra_latency")
    pods = [(int(s), b, l) for s, b, l in zip(pod_sizes, bws, lats)
            if s >= 1]
    if not pods:
        return 0.0
    total = sum(s for s, _, _ in pods)
    if total <= 1 or payload_bytes <= 0:
        return 0.0
    if any(b <= 0.0 for _, b, _ in pods):
        raise ValueError(f"intra_bw must be positive, got {intra_bw}")
    if len(pods) > 1 and (inter_bw is None or inter_bw <= 0.0):
        raise ValueError(f"inter_bw must be positive, got {inter_bw}")
    return _tree_allreduce_time(payload_bytes, CommDomain(
        bw=inter_bw if inter_bw is not None else 1.0,
        latency=inter_latency,
        children=tuple(CommDomain(bw=b, latency=l, size=s)
                       for s, b, l in pods)))


@dataclass
class TimedCommsMeter(CommsMeter):
    """CommsMeter that also accounts simulated wall-clock spent in each
    collective (the quantity async outer syncs hide behind compute).

    ``total_real_time`` separately accumulates *measured* seconds when
    an execution backend ran the collective for real (``repro.cluster.
    backend.JaxProcessBackend``); simulated and measured time live side
    by side in the log so model error is inspectable per event.
    """

    total_time: float = 0.0
    total_real_time: float = 0.0

    def record_timed(self, kind: str, participants: int, payload_bytes: int,
                     step: int, duration: float) -> float:
        self.record(kind, participants, payload_bytes, step)
        self.log[-1]["time_s"] = duration
        self.total_time += duration
        return duration

    def add_real_time(self, entry: dict, seconds: float) -> None:
        """Attach measured wire seconds to a previously recorded event
        (the runtime learns them only after the backend executes)."""
        entry["real_s"] = entry.get("real_s", 0.0) + seconds
        self.total_real_time += seconds
