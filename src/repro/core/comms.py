"""Communication accounting — the quantity AdLoCo minimizes (Theorem 2).

Counts every inter-instance parameter exchange: DiLoCo outer syncs
(all-reduce of pseudo-gradients over a trainer's M workers), MIT merges
(weighted all-reduce over the merge set), and final consolidation.
Bytes use the ring all-reduce model: 2 (p−1)/p · payload per participant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence

import jax
import numpy as np


def param_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))


@dataclass
class CommsMeter:
    events: int = 0                  # discrete sync events (paper's C(N))
    total_bytes: float = 0.0
    log: List[dict] = field(default_factory=list)

    def record(self, kind: str, participants: int, payload_bytes: int,
               step: int) -> None:
        p = max(participants, 1)
        ring = 2.0 * (p - 1) / p * payload_bytes * p   # total wire bytes
        self.events += 1
        self.total_bytes += ring
        self.log.append({"step": step, "kind": kind,
                         "participants": p, "bytes": ring})

    def snapshot(self) -> dict:
        return {"events": self.events, "bytes": self.total_bytes}


def ring_allreduce_time(payload_bytes: float, participants: int,
                        link_bw: float, latency: float = 0.0) -> float:
    """Latency + bandwidth cost of one ring all-reduce, in seconds.

    2(p−1) ring steps, each paying the per-hop latency; every node
    transmits 2(p−1)/p · payload bytes over its (slowest) link.  With
    p <= 1 there is nothing to exchange.  A non-positive bandwidth is a
    misconfiguration and fails loudly (there is no 1 byte/s floor to
    silently absorb it).
    """
    p = max(int(participants), 1)
    if p == 1 or payload_bytes <= 0:
        return 0.0
    if link_bw <= 0.0:
        raise ValueError(f"link_bw must be positive, got {link_bw}")
    steps = 2 * (p - 1)
    wire = 2.0 * (p - 1) / p * payload_bytes
    return steps * latency + wire / link_bw


def _per_pod(value, pod_sizes: Sequence[int], what: str):
    try:
        vals = [float(v) for v in value]
    except TypeError:
        return [float(value)] * len(pod_sizes)
    if len(vals) != len(pod_sizes):
        raise ValueError(f"per-pod {what} needs {len(pod_sizes)} entries, "
                         f"got {len(vals)}")
    return vals


def hierarchical_allreduce_time(payload_bytes: float,
                                pod_sizes: Sequence[int],
                                intra_bw, inter_bw: float, *,
                                intra_latency=0.0,
                                inter_latency: float = 0.0) -> float:
    """Two-level all-reduce cost over pods, in seconds.

    Models the standard hierarchical schedule: (1) ring reduce-scatter
    inside every pod (pods run in parallel; the slowest pod is the
    critical path), (2) cross-pod exchange — each node's shard rides its
    own ring over the P pods, so the critical shard is
    ``payload / min(pod_sizes)`` — and (3) ring all-gather inside every
    pod.  ``inter_bw`` is the bandwidth of one cross-pod *path* (one
    node's route to its peers in other pods), not an aggregate pipe: the
    per-node shard rings are concurrent, which is what makes the
    schedule collapse to the flat ring when cross-pod paths match node
    links.  ``intra_bw``/``intra_latency`` are single values for every
    pod or per-pod sequences aligned with ``pod_sizes`` (pods of mixed
    hardware generations have different links).  With a single pod this
    is exactly :func:`ring_allreduce_time`; with *equal pod splits* and
    cross-pod paths at least as good as node links (bandwidth and
    latency) it never exceeds the flat ring over all nodes.  A lopsided
    split can exceed the flat ring — the smallest pod sets the cross
    phase's shard granularity — which is why
    :meth:`~repro.cluster.network.Topology.allreduce_time` routes via
    the cheaper of this and the topology-priced flat ring.
    """
    bws = _per_pod(intra_bw, pod_sizes, "intra_bw")
    lats = _per_pod(intra_latency, pod_sizes, "intra_latency")
    pods = [(int(s), b, l) for s, b, l in zip(pod_sizes, bws, lats)
            if s >= 1]
    if not pods:
        return 0.0
    total = sum(s for s, _, _ in pods)
    if total <= 1 or payload_bytes <= 0:
        return 0.0
    if any(b <= 0.0 for _, b, _ in pods):
        raise ValueError(f"intra_bw must be positive, got {intra_bw}")
    if len(pods) == 1:
        return ring_allreduce_time(payload_bytes, pods[0][0], pods[0][1],
                                   pods[0][2])
    if inter_bw <= 0.0:
        raise ValueError(f"inter_bw must be positive, got {inter_bw}")
    # reduce-scatter + all-gather: (p-1) hops each, (p-1)/p of the
    # payload over the pod's slowest link each
    scatter = max((p - 1) * l + ((p - 1) / p * payload_bytes) / b
                  for p, b, l in pods)
    cross = ring_allreduce_time(payload_bytes / min(s for s, _, _ in pods),
                                len(pods), inter_bw, inter_latency)
    return 2.0 * scatter + cross


@dataclass
class TimedCommsMeter(CommsMeter):
    """CommsMeter that also accounts simulated wall-clock spent in each
    collective (the quantity async outer syncs hide behind compute)."""

    total_time: float = 0.0

    def record_timed(self, kind: str, participants: int, payload_bytes: int,
                     step: int, duration: float) -> float:
        self.record(kind, participants, payload_bytes, step)
        self.log[-1]["time_s"] = duration
        self.total_time += duration
        return duration
