"""Communication accounting — the quantity AdLoCo minimizes (Theorem 2).

Counts every inter-instance parameter exchange: DiLoCo outer syncs
(all-reduce of pseudo-gradients over a trainer's M workers), MIT merges
(weighted all-reduce over the merge set), and final consolidation.
Bytes use the ring all-reduce model: 2 (p−1)/p · payload per participant.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import jax
import numpy as np


def param_bytes(tree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))


@dataclass
class CommsMeter:
    events: int = 0                  # discrete sync events (paper's C(N))
    total_bytes: float = 0.0
    log: List[dict] = field(default_factory=list)

    def record(self, kind: str, participants: int, payload_bytes: int,
               step: int) -> None:
        p = max(participants, 1)
        ring = 2.0 * (p - 1) / p * payload_bytes * p   # total wire bytes
        self.events += 1
        self.total_bytes += ring
        self.log.append({"step": step, "kind": kind,
                         "participants": p, "bytes": ring})

    def snapshot(self) -> dict:
        return {"events": self.events, "bytes": self.total_bytes}


def ring_allreduce_time(payload_bytes: float, participants: int,
                        link_bw: float, latency: float = 0.0) -> float:
    """Latency + bandwidth cost of one ring all-reduce, in seconds.

    2(p−1) ring steps, each paying the per-hop latency; every node
    transmits 2(p−1)/p · payload bytes over its (slowest) link.  With
    p <= 1 there is nothing to exchange.
    """
    p = max(int(participants), 1)
    if p == 1 or payload_bytes <= 0:
        return 0.0
    steps = 2 * (p - 1)
    wire = 2.0 * (p - 1) / p * payload_bytes
    return steps * latency + wire / max(link_bw, 1.0)


@dataclass
class TimedCommsMeter(CommsMeter):
    """CommsMeter that also accounts simulated wall-clock spent in each
    collective (the quantity async outer syncs hide behind compute)."""

    total_time: float = 0.0

    def record_timed(self, kind: str, participants: int, payload_bytes: int,
                     step: int, duration: float) -> float:
        self.record(kind, participants, payload_bytes, step)
        self.log[-1]["time_s"] = duration
        self.total_time += duration
        return duration
