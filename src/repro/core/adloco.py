"""AdLoCo — Algorithm 3: Adaptive Batching + Merging + SwitchMode on the
DiLoCo core.  Host-level orchestrator over the jitted primitives in
``diloco.py``.

The per-trainer round body (inner steps -> batch statistics -> requested
batch update -> outer sync) lives in :class:`TrainerRound`, shared by

  * :func:`train_adloco` — the legacy synchronous host loop, and
  * ``repro.cluster.run_cluster`` — the event-driven virtual-cluster
    runtime (heterogeneous nodes, async outer syncs, elastic pools).

Ablations (paper Fig. 2) via AdLoCoConfig flags:
  adaptive=False       -> fixed-batch DiLoCo-style training
  enable_merge=False   -> no trainer consolidation
  enable_switch=False  -> no gradient accumulation (batch hard-capped)
Vanilla DiLoCo baseline = adaptive off, merge off, switch off.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import AdLoCoConfig
from repro.core import batching
from repro.core.comms import CommsMeter, param_bytes
from repro.core.diloco import (StepCache, make_outer_step, reshape_for_plan)
from repro.core.mit import (TrainerPoolState, TrainerState, check_merge,
                            consolidate, do_merge)
from repro.core.switch import ExecutionPlan, plan_execution


@dataclass
class History:
    outer_step: List[int] = field(default_factory=list)
    loss: List[float] = field(default_factory=list)
    eval_loss: List[float] = field(default_factory=list)
    # per-record {tid: eval loss} so elastic / multi-trainer runs stay
    # attributable to the trainer that produced each number
    eval_loss_by_trainer: List[Dict[int, float]] = field(default_factory=list)
    # eval loss of the batch-weighted average of the live pool at each
    # record (what ``consolidate`` would return right now) — the honest
    # convergence curve for autoscaled/elastic pools, where averaging k
    # anchors divides the gradient-noise floor; cluster runtime only
    eval_loss_pool: List[float] = field(default_factory=list)
    pool_size: List[int] = field(default_factory=list)
    requested_batches: List[List[int]] = field(default_factory=list)
    comm_events: List[int] = field(default_factory=list)
    comm_bytes: List[float] = field(default_factory=list)
    samples: List[int] = field(default_factory=list)     # cumulative
    modes: List[List[str]] = field(default_factory=list)
    wall: List[float] = field(default_factory=list)
    # simulated seconds (repro.cluster runtime only; empty for the
    # legacy host loop, which has no cluster clock)
    sim_time: List[float] = field(default_factory=list)

    def as_dict(self) -> Dict[str, Any]:
        return self.__dict__.copy()


@dataclass
class RoundOutput:
    """Result of one trainer round's compute phase (inner steps + batch
    adaptation), before the outer sync is applied."""

    worker_params: List[Any]        # per-worker end-of-round params
    x_start: Any                    # params the pseudo-gradient diffs against
    mean_loss: float
    mode: str                       # execution plan mode this round
    samples: int                    # total samples consumed (all workers)
    samples_per_worker: int
    flops_per_worker: float         # estimated compute cost (6*N*samples)
    bytes_per_worker: float         # estimated HBM traffic per worker
    # wire payload of the round's batch-stats reduction (0.0 when the
    # round ran fixed-batch); the cluster runtime prices it as a
    # collective over the trainer's nodes
    stats_bytes: float = 0.0
    # deferred-stats handle (``inner(..., defer_stats=True)``): the
    # material needed to finish the batch decision later via
    # :meth:`TrainerRound.apply_stats` — either ``{"st": GradStats}``
    # (local estimator paths, no collective needed) or
    # ``{"phase1": vec, "G_local": rows, "micro": m}`` whose phase-1
    # vector the runtime piggybacks onto the outer sync.  None when the
    # decision was applied inline (sync policy / fixed batch).
    stats_request: Optional[Dict[str, Any]] = None
    # True when the round's batch decision came from the fitted growth
    # predictor (``acfg.k_correct`` > 1, non-correction round): no stats
    # were computed and no reduction is owed (stats_bytes stays 0.0)
    predicted: bool = False


class BatchPlanProtocol:
    """Shape-agreement protocol: reduced statistics -> one batch
    decision -> one deterministic :class:`ExecutionPlan`.

    Distributed adaptive batching only works if every rank compiles the
    same shapes each round.  The protocol guarantees that by
    construction: the sufficient statistics are reduced with a
    deterministic collective (``repro.core.batching.distributed_stats``
    — every rank receives bit-identical values), and both
    :meth:`decide` and :meth:`plan_for` are pure functions of those
    values and the shared config, so the requested batch and the
    compiled ``(micro_batch, accum_steps)`` shape agree everywhere
    without any further coordination.
    """

    def __init__(self, acfg: AdLoCoConfig):
        self.acfg = acfg

    # ------------------------------------------------------- reduction
    def reduce(self, G_local, sum_reduce, *,
               micro_size: int) -> batching.GradStats:
        """Compose this process's gradient rows with every other
        process's via the backend's SUM all-reduce (exact two-phase
        composition; see ``batching.distributed_stats``)."""
        return batching.distributed_stats(G_local, sum_reduce,
                                          micro_size=micro_size)

    def payload_bytes(self, n_params: int) -> float:
        """Wire payload the runtime prices the stats collective at."""
        return batching.stats_payload_bytes(n_params)

    # ------------------------------------------- deferred (split) phases
    def begin(self, G_local) -> jnp.ndarray:
        """Phase-1 payload for a deferred reduction: the ``[colsum, b]``
        vector the runtime piggybacks onto the outer sync."""
        return batching.stats_phase1(G_local)

    def finish(self, phase1_total, G_local, sum_reduce, *,
               micro_size: int) -> batching.GradStats:
        """Finish a deferred reduction from the piggybacked phase-1
        total: phase 2 (five scalar moments) + rescale.  Bit-identical
        to the inline :meth:`reduce` composition."""
        return batching.stats_finish(phase1_total, G_local, sum_reduce,
                                     micro_size=micro_size)

    def finish_total(self, phase2_total, *,
                     micro_size: int) -> batching.GradStats:
        """Finish from an already-summed phase-2 moments vector — the
        path for backends that chained the moment reduction onto the
        outer collective's window instead of running it standalone."""
        return batching.stats_finish_total(phase2_total,
                                           micro_size=micro_size)

    # -------------------------------------------------------- decision
    def decide(self, st: batching.GradStats, current_b: int) -> int:
        """The configured batch test + monotone-growth/cap policy."""
        return batching.requested_batch(st, self.acfg, current_b)

    def plan_for(self, b_req: int) -> ExecutionPlan:
        acfg = self.acfg
        mult = (acfg.switch_multiplier if acfg.enable_switch
                else 10 ** 9)  # switch off => never accumulate
        return plan_execution(b_req, acfg.max_batch, mult)


class TrainerRound:
    """Reusable per-trainer round primitive (Alg 3 lines 17–44).

    ``inner`` runs the compute phase: M workers x H inner steps from
    ``worker_starts`` (default: the trainer's synced params), updates the
    inner optimizer states and — when adaptive — the requested batch.
    ``outer`` applies the outer (pseudo-gradient) step to the trainer and
    meters the all-reduce.  Keeping the two phases separate is what lets
    the cluster runtime overlap them (ACCO-style async outer syncs).
    """

    def __init__(self, loss_fn: Callable, acfg: AdLoCoConfig):
        self.loss_fn = loss_fn
        self.acfg = acfg
        self.protocol = BatchPlanProtocol(acfg)
        self.inner_opt = optim.get_optimizer(
            acfg.inner_optimizer, acfg.lr_inner,
            **({"weight_decay": acfg.weight_decay}
               if acfg.inner_optimizer == "adamw" else {}))
        # staleness-aware delay compensation (async policy): swap the
        # plain Nesterov outer for the delay-parameterized variant and
        # thread the measured delay through the jitted step
        self._delay_aware = (acfg.delay_compensation
                             and acfg.outer_optimizer == "nesterov")
        if self._delay_aware:
            self.outer_opt = optim.delay_compensated_nesterov(
                acfg.lr_outer, momentum=acfg.outer_momentum)
        else:
            self.outer_opt = optim.get_optimizer(
                acfg.outer_optimizer, acfg.lr_outer,
                **({"momentum": acfg.outer_momentum}
                   if acfg.outer_optimizer in ("nesterov", "sgd") else {}))
        self.cache = StepCache(loss_fn, self.inner_opt)
        self.outer_step = make_outer_step(self.outer_opt,
                                          delay_aware=self._delay_aware)
        self._n_params: Optional[int] = None
        # per-trainer batch-growth predictors (k_correct > 1): exact
        # decisions are observed, skipped rounds read the fitted line
        self._predictors: Dict[int, batching.BatchGrowthPredictor] = {}

    # ----------------------------------------------- predicted growth
    def _predictor_for(self, tid: int) -> batching.BatchGrowthPredictor:
        pred = self._predictors.get(tid)
        if pred is None:
            pred = batching.BatchGrowthPredictor(self.acfg.max_global_batch)
            self._predictors[tid] = pred
        return pred

    def _is_correction(self, round_i: Optional[int]) -> bool:
        """Rounds that run the exact stats protocol under predicted
        growth: round 1 and every ``k_correct``'th round after it.
        Everything is exact when ``k_correct <= 1`` or the caller does
        not thread round indices (legacy call sites)."""
        k = self.acfg.k_correct
        return k <= 1 or round_i is None or (round_i - 1) % k == 0

    # ---------------------------------------------------------- pool
    def init_pool(self, init_params_list: List[Any],
                  streams: List[Any]) -> TrainerPoolState:
        acfg = self.acfg
        M = acfg.nodes_per_gpu
        trainers = []
        for i, params in enumerate(init_params_list):
            trainers.append(TrainerState(
                tid=i,
                params=params,
                outer_opt_state=self.outer_opt.init(params),
                inner_opt_states=[self.inner_opt.init(params)
                                  for _ in range(M)],
                requested_batch=acfg.initial_batch_size,
                streams=[streams[i * M + m] for m in range(M)],
            ))
        return TrainerPoolState(trainers=trainers)

    def new_trainer(self, tid: int, params: Any,
                    streams: List[Any]) -> TrainerState:
        """Fresh trainer (elastic join): given params, fresh opt states."""
        M = self.acfg.nodes_per_gpu
        return TrainerState(
            tid=tid, params=params,
            outer_opt_state=self.outer_opt.init(params),
            inner_opt_states=[self.inner_opt.init(params) for _ in range(M)],
            requested_batch=self.acfg.initial_batch_size,
            streams=list(streams))

    # --------------------------------------------------------- plans
    def plan_for(self, tr: TrainerState,
                 fixed_batch: Optional[int] = None) -> ExecutionPlan:
        acfg = self.acfg
        b_req = (fixed_batch if (fixed_batch is not None
                                 and not acfg.adaptive)
                 else tr.requested_batch)
        return self.protocol.plan_for(b_req)

    def _count_params(self, params) -> int:
        if self._n_params is None:
            self._n_params = int(sum(
                jnp.size(l) for l in jax.tree.leaves(params)))
        return self._n_params

    # --------------------------------------------------------- inner
    def inner(self, tr: TrainerState, *,
              fixed_batch: Optional[int] = None,
              worker_starts: Optional[List[Any]] = None,
              workers: Optional[List[int]] = None,
              stats_reduce: Optional[Callable] = None,
              defer_stats: bool = False,
              round_i: Optional[int] = None,
              batch_share: Optional[int] = None) -> RoundOutput:
        """Compute phase of one round.  Mutates ``tr.inner_opt_states``
        and (adaptive) ``tr.requested_batch``; never touches
        ``tr.params``.  ``workers`` restricts which of the M workers this
        process computes (distributed execution backends own one worker
        per process); the returned ``worker_params`` list keeps length M
        with ``None`` at the slots other processes own.  ``stats_reduce``
        is a cross-process SUM all-reduce of a small f32 vector (see
        ``CollectiveBackend.stats_reducer``): when provided, adaptive
        batch statistics run the exact two-phase composition over every
        process's workers — each worker's microbatch-mean grad is one
        shard — so all ranks derive the identical requested batch and
        compiled shapes (the :class:`BatchPlanProtocol` contract).
        ``defer_stats`` (async policy) skips the inline batch decision
        and instead returns a stale stats handle in
        ``RoundOutput.stats_request``; the runtime piggybacks its
        phase-1 vector onto the outer sync and folds the decision via
        :meth:`apply_stats` when that collective lands — one-round-stale
        plan semantics, same on every backend by construction.
        ``round_i`` (1-based outer round) enables predicted batch growth
        when ``acfg.k_correct > 1``: non-correction rounds set the
        requested batch from the fitted exponential trajectory with zero
        stats collectives.  ``batch_share`` (autoscaling runtimes)
        overrides the *executed* plan to this trainer's slice of the
        requested batch without touching the decision trajectory."""
        acfg = self.acfg
        M = len(tr.inner_opt_states)
        H = acfg.num_inner_steps
        idxs = list(range(M)) if workers is None else list(workers)
        plan = self.plan_for(tr, fixed_batch)
        if batch_share is not None and acfg.adaptive:
            plan = self.protocol.plan_for(max(1, int(batch_share)))
        step_fn = self.cache.get(plan)

        x_start = tr.params
        worker_params: List[Any] = [None] * M
        worker_grads, last_losses = [], []
        for m in idxs:
            wp = worker_starts[m] if worker_starts is not None else x_start
            opt_m = tr.inner_opt_states[m]
            stream = tr.streams[m % len(tr.streams)]
            for h in range(H):
                batch = stream.next_batch(plan.effective_batch)
                batch = reshape_for_plan(batch, plan)
                wp, opt_m, loss, grads = step_fn(wp, opt_m, batch)
            worker_params[m] = wp
            worker_grads.append(grads)
            tr.inner_opt_states[m] = opt_m
            last_losses.append(float(loss))

        # ---- requested batch for the next round (Alg 3 line 31) ------
        stats_bytes = 0.0
        stats_request: Optional[Dict[str, Any]] = None
        predicted = False
        if acfg.adaptive and not self._is_correction(round_i):
            # PadaDamp-style skipped round: read the fitted exponential
            # trajectory instead of running the stats reduction — zero
            # collectives, every rank fits the same observations so the
            # shape-agreement contract holds without communication
            tr.requested_batch = self._predictor_for(tr.tid).predict(
                round_i, tr.requested_batch)
            predicted = True
        elif acfg.adaptive:
            n = self._count_params(x_start)
            if stats_reduce is not None:
                # distributed backends: each process contributes its
                # workers' microbatch-mean grads as shards of the exact
                # two-phase composition; every rank receives identical
                # reduced statistics, so the decision below agrees by
                # construction (shape-agreement protocol)
                G_local = batching.flatten_grads(
                    jax.tree.map(lambda *g: jnp.stack(g), *worker_grads))
                if defer_stats:
                    st = None
                    stats_request = {"phase1": self.protocol.begin(G_local),
                                     "G_local": G_local,
                                     "micro": plan.effective_batch}
                else:
                    st = self.protocol.reduce(
                        G_local, stats_reduce,
                        micro_size=plan.effective_batch)
            elif acfg.stats_estimator == "microbatch" and len(idxs) >= 2:
                # free distributed estimator: the M workers' last
                # microbatch-mean grads are already materialized;
                # Var over workers * m estimates sigma^2 with zero
                # extra passes (DESIGN.md §3 — the grads come from
                # slightly diverged worker params, an accepted
                # approximation of the shared-point statistics)
                stack = jax.tree.map(lambda *g: jnp.stack(g),
                                     *worker_grads)
                st = batching.stats_from_microbatch_grads(
                    stack, plan.effective_batch,
                    use_kernel=acfg.stats_use_kernel)
            else:
                # the paper computes sigma_Bk / grad_Bk on the
                # CURRENT batch; stats_probe_size is only a memory
                # cap (the E||g_B||^2 = ||g||^2 + sigma^2/B bias of
                # a too-small probe stalls batch growth and breaks
                # Theorem 2's ln-N communication profile)
                probe_b = max(4, min(acfg.stats_probe_size,
                                     plan.effective_batch))
                probe = tr.streams[0].next_batch(probe_b)
                st = batching.per_sample_stats(
                    self.loss_fn, worker_params[idxs[0]], probe,
                    use_kernel=acfg.stats_use_kernel)
            if defer_stats:
                # one-round-stale plan semantics: the decision folds at
                # the outer sync's landing point (apply_stats), not here
                if stats_request is None:
                    stats_request = {"st": st}
            else:
                tr.requested_batch = self.protocol.decide(
                    st, tr.requested_batch)
                if acfg.k_correct > 1 and round_i is not None:
                    self._predictor_for(tr.tid).observe(
                        round_i, tr.requested_batch)
            stats_bytes = self.protocol.payload_bytes(n)

        spw = plan.effective_batch * H
        n = self._count_params(x_start)
        return RoundOutput(
            worker_params=worker_params, x_start=x_start,
            # a rank outside this trainer's process group computes no
            # workers; its zero contribution drops out of the backend's
            # group-masked loss mean
            mean_loss=(sum(last_losses) / len(last_losses)
                       if last_losses else 0.0),
            mode=plan.mode, samples=spw * M, samples_per_worker=spw,
            flops_per_worker=6.0 * n * spw,
            bytes_per_worker=3.0 * param_bytes(x_start) * H,
            stats_bytes=stats_bytes, stats_request=stats_request,
            predicted=predicted)

    # ---------------------------------------------------- stale stats
    def apply_stats(self, tr: TrainerState, request: Dict[str, Any], *,
                    phase1_total=None, phase2_total=None,
                    sum_reduce: Optional[Callable] = None,
                    round_i: Optional[int] = None) -> int:
        """Fold a stale stats handle produced by
        ``inner(..., defer_stats=True)`` into the trainer's requested
        batch.  Local-estimator requests carry the finished statistics
        (``{"st"}``); distributed requests carry the phase-1 material —
        the caller supplies either ``phase2_total`` (the five-moment
        SUM a backend chained onto the outer collective's in-flight
        window) or ``phase1_total`` (the piggybacked SUM of every
        rank's phase-1 vector) plus ``sum_reduce`` for the standalone
        phase-2 moment reduction.  Returns the updated requested batch
        (identical on every rank — the shape-agreement contract)."""
        if "st" in request:
            st = request["st"]
        elif phase2_total is not None:
            st = self.protocol.finish_total(
                phase2_total, micro_size=request["micro"])
        else:
            st = self.protocol.finish(
                phase1_total, request["G_local"], sum_reduce,
                micro_size=request["micro"])
        tr.requested_batch = self.protocol.decide(st, tr.requested_batch)
        if self.acfg.k_correct > 1 and round_i is not None:
            self._predictor_for(tr.tid).observe(round_i, tr.requested_batch)
        return tr.requested_batch

    # --------------------------------------------------------- outer
    def outer(self, tr: TrainerState, worker_params: List[Any], *,
              x_prev: Optional[Any] = None,
              comms: Optional[CommsMeter] = None, step: int = 0,
              reduce: Optional[Callable] = None,
              delay: float = 0.0) -> None:
        """Apply the outer (pseudo-gradient) step: Alg 3 lines 40–44.
        ``x_prev`` defaults to the trainer's current synced params; the
        async cluster policy passes the anchor captured at launch time
        (delayed application).  ``reduce`` maps the per-worker params
        list to the worker-stacked pytree ``make_outer_step`` averages —
        the default is the in-process ``jnp.stack``; execution backends
        substitute a real cross-process collective that returns the
        already-reduced (1, ...) mean.  ``delay`` is the measured
        staleness in rounds (how many inner rounds folded between the
        snapshot and this application); with ``delay_compensation`` on
        it damps the momentum contribution accordingly, otherwise it is
        ignored by the jitted step."""
        if reduce is None:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *worker_params)
        else:
            stacked = reduce(worker_params)
        tr.params, tr.outer_opt_state = self.outer_step(
            x_prev if x_prev is not None else tr.params,
            stacked, tr.outer_opt_state, float(delay))
        if comms is not None:
            comms.record("outer", participants=len(worker_params),
                         payload_bytes=param_bytes(tr.params), step=step)


def record_eval(hist: History, pool: TrainerPoolState,
                eval_fn: Optional[Callable]) -> None:
    """Evaluate every trainer, keep the per-tid map, and track the best
    (largest requested batch = most advanced) trainer's loss in the
    legacy ``eval_loss`` series."""
    if eval_fn is None:
        return
    per = {tr.tid: float(eval_fn(tr.params)) for tr in pool.trainers}
    hist.eval_loss_by_trainer.append(per)
    best = max(pool.trainers, key=lambda tr: tr.requested_batch)
    hist.eval_loss.append(per[best.tid])


def train_adloco(loss_fn: Callable, init_params_list: List[Any],
                 streams: List[Any], acfg: AdLoCoConfig, *,
                 num_outer_steps: Optional[int] = None,
                 eval_fn: Optional[Callable] = None,
                 fixed_batch: Optional[int] = None,
                 verbose: bool = False,
                 restore_from: Optional[tuple] = None):
    """Run Algorithm 3 (synchronous host loop).

    loss_fn(params, batch) -> (loss, aux);  streams: k*M data shards with
    ``next_batch(b)``;  init_params_list: k independent inits (the paper's
    multi-instance diversity).  ``restore_from``: optional
    (ckpt_dir, step) to restore the trainer pool from before training.
    Returns (TrainerPoolState, History).
    """
    T = num_outer_steps or acfg.num_outer_steps
    rnd = TrainerRound(loss_fn, acfg)
    pool = rnd.init_pool(init_params_list, streams)
    if restore_from is not None:
        from repro.checkpoint import restore_train_state
        pool, _ = restore_train_state(restore_from[0], restore_from[1], pool)
    if fixed_batch is not None and not acfg.adaptive:
        for tr in pool.trainers:
            tr.requested_batch = fixed_batch
    hist = History()
    samples_total = 0
    t0 = time.time()

    for t in range(1, T + 1):
        # ---- CheckMerge / DoMerge (Alg 3 lines 11–16) ----------------
        if (acfg.enable_merge and pool.k > 1
                and t % acfg.merge_frequency == 0):
            ids = check_merge([tr.requested_batch for tr in pool.trainers],
                              acfg.merge_w + 1)  # w worst + representative
            if len(ids) > 1:
                pool = do_merge(pool, ids, step=t)

        round_losses, modes = [], []
        for tr in pool.trainers:
            out = rnd.inner(tr, fixed_batch=fixed_batch, round_i=t)
            round_losses.append(out.mean_loss)
            modes.append(out.mode)
            samples_total += out.samples
            # ---- outer sync (Alg 3 lines 40–44) ----------------------
            rnd.outer(tr, out.worker_params, comms=pool.comms, step=t)

        hist.outer_step.append(t)
        hist.loss.append(sum(round_losses) / len(round_losses))
        hist.pool_size.append(pool.k)
        hist.requested_batches.append(
            [tr.requested_batch for tr in pool.trainers])
        hist.comm_events.append(pool.comms.events)
        hist.comm_bytes.append(pool.comms.total_bytes)
        hist.samples.append(samples_total)
        hist.modes.append(modes)
        hist.wall.append(time.time() - t0)
        record_eval(hist, pool, eval_fn)
        if verbose:
            print(f"[adloco] t={t} loss={hist.loss[-1]:.4f} "
                  f"k={pool.k} b={hist.requested_batches[-1]} "
                  f"comm={pool.comms.events}")

    pool = consolidate(pool, step=T)
    return pool, hist
