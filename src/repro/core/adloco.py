"""AdLoCo — Algorithm 3: Adaptive Batching + Merging + SwitchMode on the
DiLoCo core.  Host-level orchestrator over the jitted primitives in
``diloco.py``.

Ablations (paper Fig. 2) via AdLoCoConfig flags:
  adaptive=False       -> fixed-batch DiLoCo-style training
  enable_merge=False   -> no trainer consolidation
  enable_switch=False  -> no gradient accumulation (batch hard-capped)
Vanilla DiLoCo baseline = adaptive off, merge off, switch off.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import AdLoCoConfig
from repro.core import batching
from repro.core.comms import CommsMeter, param_bytes
from repro.core.diloco import (StepCache, make_outer_step, reshape_for_plan)
from repro.core.mit import (TrainerPoolState, TrainerState, check_merge,
                            consolidate, do_merge)
from repro.core.switch import ExecutionPlan, plan_execution


@dataclass
class History:
    outer_step: List[int] = field(default_factory=list)
    loss: List[float] = field(default_factory=list)
    eval_loss: List[float] = field(default_factory=list)
    pool_size: List[int] = field(default_factory=list)
    requested_batches: List[List[int]] = field(default_factory=list)
    comm_events: List[int] = field(default_factory=list)
    comm_bytes: List[float] = field(default_factory=list)
    samples: List[int] = field(default_factory=list)     # cumulative
    modes: List[List[str]] = field(default_factory=list)
    wall: List[float] = field(default_factory=list)

    def as_dict(self) -> Dict[str, Any]:
        return self.__dict__.copy()


def _make_trainers(init_params_list, streams, acfg: AdLoCoConfig,
                   inner_opt, outer_opt) -> List[TrainerState]:
    k, M = len(init_params_list), acfg.nodes_per_gpu
    trainers = []
    for i, params in enumerate(init_params_list):
        trainers.append(TrainerState(
            tid=i,
            params=params,
            outer_opt_state=outer_opt.init(params),
            inner_opt_states=[inner_opt.init(params) for _ in range(M)],
            requested_batch=acfg.initial_batch_size,
            streams=[streams[i * M + m] for m in range(M)],
        ))
    return trainers


def train_adloco(loss_fn: Callable, init_params_list: List[Any],
                 streams: List[Any], acfg: AdLoCoConfig, *,
                 num_outer_steps: Optional[int] = None,
                 eval_fn: Optional[Callable] = None,
                 fixed_batch: Optional[int] = None,
                 verbose: bool = False,
                 restore_from: Optional[tuple] = None):
    """Run Algorithm 3.

    loss_fn(params, batch) -> (loss, aux);  streams: k*M data shards with
    ``next_batch(b)``;  init_params_list: k independent inits (the paper's
    multi-instance diversity).  ``restore_from``: optional
    (ckpt_dir, step) to restore the trainer pool from before training.
    Returns (TrainerPoolState, History).
    """
    T = num_outer_steps or acfg.num_outer_steps
    M = acfg.nodes_per_gpu
    H = acfg.num_inner_steps
    inner_opt = optim.get_optimizer(
        acfg.inner_optimizer, acfg.lr_inner,
        **({"weight_decay": acfg.weight_decay}
           if acfg.inner_optimizer == "adamw" else {}))
    outer_opt = optim.get_optimizer(
        acfg.outer_optimizer, acfg.lr_outer,
        **({"momentum": acfg.outer_momentum}
           if acfg.outer_optimizer in ("nesterov", "sgd") else {}))
    cache = StepCache(loss_fn, inner_opt)
    outer_step = make_outer_step(outer_opt)

    pool = TrainerPoolState(
        trainers=_make_trainers(init_params_list, streams, acfg,
                                inner_opt, outer_opt))
    if restore_from is not None:
        from repro.checkpoint import restore_train_state
        pool, _ = restore_train_state(restore_from[0], restore_from[1], pool)
    if fixed_batch is not None and not acfg.adaptive:
        for tr in pool.trainers:
            tr.requested_batch = fixed_batch
    hist = History()
    samples_total = 0
    t0 = time.time()

    for t in range(1, T + 1):
        # ---- CheckMerge / DoMerge (Alg 3 lines 11–16) ----------------
        if (acfg.enable_merge and pool.k > 1
                and t % acfg.merge_frequency == 0):
            ids = check_merge([tr.requested_batch for tr in pool.trainers],
                              acfg.merge_w + 1)  # w worst + representative
            if len(ids) > 1:
                pool = do_merge(pool, ids, step=t)

        round_losses, modes = [], []
        for tr in pool.trainers:
            b_req = (fixed_batch if (fixed_batch is not None
                                     and not acfg.adaptive)
                     else tr.requested_batch)
            mult = (acfg.switch_multiplier if acfg.enable_switch
                    else 10 ** 9)  # switch off => never accumulate
            plan = plan_execution(b_req, acfg.max_batch, mult)
            modes.append(plan.mode)
            step_fn = cache.get(plan)

            x_start = tr.params
            worker_params = []
            worker_grads = []
            last_losses = []
            for m in range(M):
                wp = x_start
                opt_m = tr.inner_opt_states[m]
                stream = tr.streams[m % len(tr.streams)]
                for h in range(H):
                    batch = stream.next_batch(plan.effective_batch)
                    batch = reshape_for_plan(batch, plan)
                    wp, opt_m, loss, grads = step_fn(wp, opt_m, batch)
                    samples_total += plan.effective_batch
                worker_params.append(wp)
                worker_grads.append(grads)
                tr.inner_opt_states[m] = opt_m
                last_losses.append(float(loss))
            round_losses.append(sum(last_losses) / len(last_losses))

            # ---- requested batch for the next round (Alg 3 line 31) --
            if acfg.adaptive:
                if acfg.stats_estimator == "microbatch" and M >= 2:
                    # free distributed estimator: the M workers' last
                    # microbatch-mean grads are already materialized;
                    # Var over workers * m estimates sigma^2 with zero
                    # extra passes (DESIGN.md §3 — the grads come from
                    # slightly diverged worker params, an accepted
                    # approximation of the shared-point statistics)
                    stack = jax.tree.map(lambda *g: jnp.stack(g),
                                         *worker_grads)
                    st = batching.stats_from_microbatch_grads(
                        stack, plan.effective_batch)
                else:
                    # the paper computes sigma_Bk / grad_Bk on the
                    # CURRENT batch; stats_probe_size is only a memory
                    # cap (the E||g_B||^2 = ||g||^2 + sigma^2/B bias of
                    # a too-small probe stalls batch growth and breaks
                    # Theorem 2's ln-N communication profile)
                    probe_b = max(4, min(acfg.stats_probe_size,
                                         plan.effective_batch))
                    probe = tr.streams[0].next_batch(probe_b)
                    st = batching.per_sample_stats(
                        loss_fn, worker_params[0], probe)
                tr.requested_batch = batching.requested_batch(
                    st, acfg, tr.requested_batch)

            # ---- outer sync (Alg 3 lines 40–44) -----------------------
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *worker_params)
            tr.params, tr.outer_opt_state = outer_step(
                x_start, stacked, tr.outer_opt_state)
            pool.comms.record("outer", participants=M,
                              payload_bytes=param_bytes(tr.params), step=t)

        hist.outer_step.append(t)
        hist.loss.append(sum(round_losses) / len(round_losses))
        hist.pool_size.append(pool.k)
        hist.requested_batches.append(
            [tr.requested_batch for tr in pool.trainers])
        hist.comm_events.append(pool.comms.events)
        hist.comm_bytes.append(pool.comms.total_bytes)
        hist.samples.append(samples_total)
        hist.modes.append(modes)
        hist.wall.append(time.time() - t0)
        if eval_fn is not None:
            best = min(pool.trainers, key=lambda tr: -tr.requested_batch)
            hist.eval_loss.append(float(eval_fn(best.params)))
        if verbose:
            print(f"[adloco] t={t} loss={hist.loss[-1]:.4f} "
                  f"k={pool.k} b={hist.requested_batches[-1]} "
                  f"comm={pool.comms.events}")

    pool = consolidate(pool, step=T)
    return pool, hist
