"""Architecture registry.  ``get_config("qwen3-0.6b")`` or ``--arch`` ids."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    INPUT_SHAPES,
    AdLoCoConfig,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

from repro.configs import (  # noqa: E402
    qwen3_0_6b,
    phi3_medium_14b,
    deepseek_moe_16b,
    stablelm_1_6b,
    hymba_1_5b,
    grok_1_314b,
    gemma3_4b,
    phi3_vision_4_2b,
    whisper_small,
    falcon_mamba_7b,
    microllama_300m,
)

ARCH_REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_0_6b,
        phi3_medium_14b,
        deepseek_moe_16b,
        stablelm_1_6b,
        hymba_1_5b,
        grok_1_314b,
        gemma3_4b,
        phi3_vision_4_2b,
        whisper_small,
        falcon_mamba_7b,
        microllama_300m,
    )
}

# The ten assigned architectures (microllama is the paper's own extra).
ASSIGNED_ARCHS = [
    "qwen3-0.6b",
    "phi3-medium-14b",
    "deepseek-moe-16b",
    "stablelm-1.6b",
    "hymba-1.5b",
    "grok-1-314b",
    "gemma3-4b",
    "phi-3-vision-4.2b",
    "whisper-small",
    "falcon-mamba-7b",
]

# Archs allowed to lower the long_500k decode shape (sub-quadratic path:
# SSM / hybrid / sliding-window).  Skips are documented in DESIGN.md §5.
LONG_CONTEXT_ARCHS = {"gemma3-4b", "hymba-1.5b", "falcon-mamba-7b"}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCH_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_REGISTRY)}"
        ) from None


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, small vocab.  Used by per-arch CPU smoke tests."""
    d_model = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    # keep the GQA ratio representative when possible
    if cfg.num_kv_heads < cfg.num_heads:
        kv = max(1, heads // 2)
    head_dim = 64 if cfg.head_dim is not None else None
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            d_expert=128,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_dim=8, conv_dim=4, expand=2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=2,
        encoder_layers=2 if cfg.is_encoder_decoder else 0,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=0 if cfg.arch_type == "ssm" else min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        num_prefix_tokens=min(cfg.num_prefix_tokens, 16),
        moe=moe,
        ssm=ssm,
        dtype="float32",
    )


__all__ = [
    "ARCH_REGISTRY",
    "ASSIGNED_ARCHS",
    "LONG_CONTEXT_ARCHS",
    "INPUT_SHAPES",
    "AdLoCoConfig",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "get_config",
    "reduced",
]
