"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free.  [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,            # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    citation="arXiv:2410.05355 (Falcon Mamba 7B)",
)
