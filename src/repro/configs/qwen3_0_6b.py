"""qwen3-0.6b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,          # Qwen3 uses head_dim 128 decoupled from d_model
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="hf:Qwen/Qwen3-8B (0.6B variant card)",
)
