"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct]

The vision encoder + projector is a STUB per assignment: input_specs()
provides precomputed patch embeddings (batch, 576, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    frontend="vision",
    num_prefix_tokens=576,   # 24x24 CLIP-L/14 patch grid at 336px
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
)
