"""gemma3-4b [dense] — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    arch_type="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10_240,
    vocab_size=262_144,
    sliding_window=1024,
    global_every=6,        # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="hf:google/gemma-3-1b-pt (gemma-3 family, 4B config)",
)
