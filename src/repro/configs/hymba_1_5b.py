"""hymba-1.5b [hybrid] — parallel attn + mamba heads.  [arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    hybrid=True,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    # Hymba uses sliding-window attention in all but a few global layers
    # (arXiv:2411.13676 §2): modeled as a 15:1 local:global pattern.
    sliding_window=1024,
    global_every=16,
    citation="arXiv:2411.13676 (Hymba 1.5B)",
)
