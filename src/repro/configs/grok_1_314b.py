"""grok-1-314b [moe] — 8 experts top-2.  [hf:xai-org/grok-1]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=0, d_expert=32_768),
    citation="hf:xai-org/grok-1",
)
