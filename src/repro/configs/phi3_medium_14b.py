"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.  [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17_920,
    vocab_size=100_352,
    rope_theta=10_000.0,
    citation="arXiv:2404.14219 (Phi-3 technical report, medium 14B)",
)
