"""whisper-small [audio] — enc-dec, conv frontend (stub).  [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per assignment:
input_specs() provides precomputed frame embeddings (batch, 1500, d_model)
which the 12-layer encoder consumes; the 12-layer decoder cross-attends."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    is_encoder_decoder=True,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    frontend="audio",
    num_prefix_tokens=1500,   # 30 s audio -> 1500 frames after conv stride 2
    rope_theta=10_000.0,      # (whisper uses learned pos; we use RoPE — noted in DESIGN)
    citation="arXiv:2212.04356 (Whisper, small)",
)
