"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained.
[arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                     # per fine-grained expert
    vocab_size=102_400,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    citation="arXiv:2401.06066 (DeepSeekMoE 16B)",
)
