"""Config system: frozen dataclasses describing model architectures and
input shapes.

Every assigned architecture gets one module in this package exporting a
``CONFIG: ModelConfig``; the registry in ``__init__`` maps ``--arch`` ids
to them.  Configs are pure data — no jax imports here, so importing a
config never touches device state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int              # routed experts
    top_k: int                    # experts per token
    num_shared: int = 0           # always-on shared experts (DeepSeekMoE)
    d_expert: Optional[int] = None  # per-expert FFN hidden dim (None -> d_ff)
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01
    # "flat": one global dispatch over all T tokens; "grouped": per-batch-
    # row dispatch (Switch-style per-device capacity) — keeps the (E,C,d)
    # dispatch buffer data-sharded.  Right choice is arch-dependent: wins
    # on fine-grained many-expert MoE (deepseek: the flat buffer is 2x the
    # activations and gets all-gathered), loses on few-big-expert MoE
    # (grok: §Perf pair-3 it.2).
    dispatch: str = "flat"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective-state-space configuration."""

    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # None -> ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``arch_type`` is one of: dense | moe | ssm | hybrid | vlm | audio.
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    head_dim: Optional[int] = None          # None -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # Sliding-window attention (gemma3): window size and "every Nth layer
    # is global" pattern (5 local : 1 global => global_every=6).
    sliding_window: Optional[int] = None
    global_every: Optional[int] = None

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid: parallel attention + SSM heads within each layer (hymba)
    hybrid: bool = False

    # encoder-decoder (whisper): encoder depth; decoder depth = num_layers
    encoder_layers: int = 0
    is_encoder_decoder: bool = False

    # modality frontend STUB: 'vision' | 'audio' | None.  input_specs()
    # provides precomputed embeddings of shape (batch, num_prefix_tokens,
    # d_model) — per assignment, the frontend itself is not implemented.
    frontend: Optional[str] = None
    num_prefix_tokens: int = 0

    dtype: str = "bfloat16"

    # ----- derived ---------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        if self.ssm.dt_rank is not None:
            return self.ssm.dt_rank
        return -(-self.d_model // 16)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for roofline MODEL_FLOPS = 6*N*D).
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        # embeddings (+ output head unless tied)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.arch_type == "ssm":
            # mamba block only
            per_layer += self._mamba_params()
            per_layer += d  # norm
        else:
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qk_norm:
                attn += 2 * hd
            per_layer += attn + d  # + attn norm
            if self.hybrid:
                per_layer += self._mamba_params()
            if self.moe is not None:
                de = self.moe.d_expert or self.d_ff
                routed = self.moe.num_experts * 3 * d * de
                shared = self.moe.num_shared * 3 * d * de
                router = d * self.moe.num_experts
                per_layer += (routed if not active_only else self.moe.top_k * 3 * d * de) + shared + router
            else:
                per_layer += 3 * d * self.d_ff  # SwiGLU: gate, up, down
            per_layer += d  # mlp norm
        n += self.num_layers * per_layer
        if self.is_encoder_decoder:
            # encoder self-attn + ffn, decoder cross-attn
            enc = self.encoder_layers * (4 * d * d + 3 * d * self.d_ff + 2 * d)
            xattn = self.num_layers * (4 * d * d + d)
            n += enc + xattn
        n += d  # final norm
        return n

    def _mamba_params(self) -> int:
        d = self.d_model
        ssm = self.ssm or SSMConfig()
        di = ssm.expand * d
        dtr = self.dt_rank if self.ssm is not None else -(-d // 16)
        n = 0
        n += d * 2 * di                     # in_proj (x and z)
        n += di * ssm.conv_dim              # depthwise conv
        n += di * (dtr + 2 * ssm.state_dim)  # x -> (dt, B, C)
        n += dtr * di                       # dt_proj
        n += di * ssm.state_dim             # A_log
        n += di                             # D
        n += di * d                         # out_proj
        return n


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class AdLoCoConfig:
    """Paper Table 1 hyperparameters + switch/merge policy knobs."""

    num_outer_steps: int = 20
    num_inner_steps: int = 200          # H
    lr_inner: float = 2e-5
    lr_outer: float = 0.5
    outer_momentum: float = 0.9         # DiLoCo uses Nesterov outer
    num_init_trainers: int = 4          # k
    nodes_per_gpu: int = 4              # M workers per trainer
    initial_batch_size: int = 1
    merge_frequency: int = 3
    merge_w: int = 1                    # merge w worst trainers
    eta: float = 0.8                    # norm-test η
    theta: float = 0.01                 # inner-product-test ϑ
    nu: float = 0.3                     # augmented-test ν
    max_batch: int = 64                 # b_max per device
    switch_multiplier: int = 2          # n: accumulate when b_req > n*b_max
    batch_test: str = "norm"            # norm | inner_product | augmented
    max_global_batch: int = 4096        # hard cap (safety)
    weight_decay: float = 0.1
    seed: int = 0

    # ablation switches (paper Fig. 2): turning these off yields the
    # "-adaptive", "-merge", "-switch" variants; all three off + k=1
    # recovers vanilla DiLoCo.
    adaptive: bool = True
    enable_merge: bool = True
    enable_switch: bool = True
    stats_probe_size: int = 64          # samples used for batching stats
    # "per_sample": exact vmap-of-grad probe (the paper's estimator).
    # "microbatch": free distributed estimator — variance of the M
    #   workers' microbatch-mean gradients that data parallelism already
    #   materializes (sigma^2 = m * Var(G_j)); zero extra forward/backward
    #   cost, requires M >= 2 (falls back to per_sample otherwise).
    stats_estimator: str = "per_sample"
    # route the (B, D) stats reduction through the fused gradstats
    # Pallas kernel instead of the pure-jnp oracle (same numbers to
    # float tolerance; the kernel streams HBM twice instead of thrice)
    stats_use_kernel: bool = False
    inner_optimizer: str = "adamw"
    outer_optimizer: str = "nesterov"
    # staleness-aware delay compensation for delayed (async) outer
    # application: scale the Nesterov momentum contribution by
    # 1/(1 + measured delay in rounds).  Off by default so every
    # synchronous trajectory stays bit-identical; turn on to run
    # outer_momentum=0.9 under the async policy's one-round staleness
    # (underdamped without it — see repro.cluster docs).
    delay_compensation: bool = False
    # merge drift window (rounds): maybe_merge skips trainers whose
    # round counter lags the merge round by more than this instead of
    # stalling the whole merge until the slowest trainer catches up
    merge_drift_window: int = 1
    # PadaDamp-style predicted batch growth (Lau et al., arXiv
    # 2406.13936): run the exact gradient-order stats reduction only
    # every k_correct rounds and, in between, set the requested batch
    # from a fitted exponential growth trajectory — zero collectives on
    # the skipped rounds, with the exact protocol as the periodic
    # correction.  1 (default) = exact every round, the legacy behavior.
    k_correct: int = 1
