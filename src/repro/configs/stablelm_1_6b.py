"""stablelm-1.6b [dense].  [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    citation="hf:stabilityai/stablelm-2-1_6b",
)
