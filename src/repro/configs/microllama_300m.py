"""microllama-300m — the paper's own experiment model.  [Wang 2024,
hf:keeeeenw/MicroLlama]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="microllama-300m",
    arch_type="dense",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32_000,
    citation="hf:keeeeenw/MicroLlama (paper's experiment model)",
)
