"""Pytree checkpointing to .npz + JSON sidecar (no orbax offline).

Handles the full AdLoCo training state: per-trainer params, inner/outer
optimizer states, adaptive-batch state, and pool metadata.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16; f32 holds every bf16 exactly (round-trip
            # lossless — restore casts back to the template dtype)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save_pytree(path: str, tree) -> None:
    arrays, _ = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def restore_pytree(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, leaf in flat:
            key = jax.tree_util.keystr(p)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def save_json(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=str)


def load_json(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def save_train_state(ckpt_dir: str, step: int, pool_state) -> None:
    """pool_state: repro.core.mit.TrainerPoolState."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    for i, tr in enumerate(pool_state.trainers):
        save_pytree(os.path.join(d, f"trainer_{i}_params.npz"), tr.params)
        save_pytree(os.path.join(d, f"trainer_{i}_outer_opt.npz"),
                    tr.outer_opt_state)
        for m, st in enumerate(tr.inner_opt_states):
            save_pytree(os.path.join(d, f"trainer_{i}_inner_opt_{m}.npz"), st)
    if pool_state.global_params is not None:
        save_pytree(os.path.join(d, "global_params.npz"),
                    pool_state.global_params)
    save_json(os.path.join(d, "meta.json"), {
        "step": step,
        "num_trainers": len(pool_state.trainers),
        "requested_batches": [int(t.requested_batch) for t in pool_state.trainers],
        "comms_bytes": float(pool_state.comms.total_bytes),
        "comms_events": int(pool_state.comms.events),
    })


def restore_train_state(ckpt_dir: str, step: int, pool_state):
    """Restore a checkpoint *in place* into ``pool_state`` (a
    TrainerPoolState whose trainers provide shape/dtype templates —
    i.e. freshly initialised with the same config/pool size)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    meta = load_json(os.path.join(d, "meta.json"))
    assert meta["num_trainers"] == len(pool_state.trainers), \
        (meta["num_trainers"], len(pool_state.trainers))
    for i, tr in enumerate(pool_state.trainers):
        tr.params = restore_pytree(
            os.path.join(d, f"trainer_{i}_params.npz"), tr.params)
        tr.outer_opt_state = restore_pytree(
            os.path.join(d, f"trainer_{i}_outer_opt.npz"),
            tr.outer_opt_state)
        tr.inner_opt_states = [
            restore_pytree(os.path.join(d, f"trainer_{i}_inner_opt_{m}.npz"),
                           st)
            for m, st in enumerate(tr.inner_opt_states)]
        tr.requested_batch = int(meta["requested_batches"][i])
    gp = os.path.join(d, "global_params.npz")
    if os.path.exists(gp) and pool_state.trainers:
        pool_state.global_params = restore_pytree(
            gp, pool_state.trainers[0].params)
    return pool_state, meta


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
             if n.startswith("step_")]
    return max(steps) if steps else None
