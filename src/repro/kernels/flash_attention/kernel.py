"""Blocked online-softmax (flash) attention Pallas kernel — TPU target.

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_head, Sq/BQ, Sk/BK); the last axis is sequential
    ("arbitrary" dimension semantics) and carries the online-softmax
    state (m, l, acc) in VMEM scratch.
  * BQ = BK = 128 aligns the s = q·kᵀ and p·v contractions with the
    128×128 MXU tile; head_dim rides the lane dimension.
  * GQA is handled in the k/v index_map (kv head = q head // group) —
    no materialized head repeat in HBM.
  * causal + sliding-window masking from block-local iotas; the window
    is a *dynamic* scalar (scalar-prefetch) so one compiled kernel
    serves gemma3's interleaved local/global layers under lax.scan.
      VMEM working set per step: BQ·hd (q) + 2·BK·hd (k,v) + BQ·BK (s)
    + BQ·hd (acc) floats ≈ 0.4 MB at hd=128 — comfortably inside 16 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(window_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bq: int, bk: int, scale: float,
                  causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (BQ, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (BK, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)          # (BK, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    window = window_ref[0]
    mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (BQ,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l > 0, l, 1.0)[:, None]
        o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_padded(q, k, v, window, *, causal: bool = True,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q: (B,S,H,hd), k/v: (B,S,Hk,hd), S divisible by bq/bk.
    window: int32 (1,) — keys with kpos <= qpos - window are masked
    (use a huge value for full attention)."""
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    grid = (B, H, nq, nk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, 1, hd),
                             lambda b, h, iq, ik, w: (b, iq, h, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, iq, ik, w: (b, ik, h // G, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, iq, ik, w: (b, ik, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, 1, hd),
                                   lambda b, h, iq, ik, w: (b, iq, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, hd), jnp.float32),
                pltpu.VMEM((bq,), jnp.float32),
                pltpu.VMEM((bq,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(window, q, k, v)
