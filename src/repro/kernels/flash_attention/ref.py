"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import sdpa


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q (B,S,H,hd), k/v (B,S,Hk,hd) -> (B,S,H,hd)."""
    return sdpa(q, k, v, causal=causal, window=window)
