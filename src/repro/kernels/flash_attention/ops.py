"""jit'd public wrapper: padding, window normalization, CPU interpret
fallback.  Forward-only (serving / prefill); the training path uses the
XLA reference — Pallas kernels have no implicit VJP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_padded
from repro.models.layers import GLOBAL_WINDOW


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128):
    """q (B,S,H,hd), k/v (B,S,Hk,hd) -> (B,S,H,hd).

    ``window``: None (full), python int, or traced int32 scalar (dynamic
    per-layer windows under lax.scan).
    """
    B, S, H, hd = q.shape
    bq = min(bq, max(8, S))
    bk = min(bk, max(8, S))
    pad = (-S) % max(bq, bk)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    if window is None:
        w = jnp.full((1,), GLOBAL_WINDOW, jnp.int32)
    else:
        w = jnp.asarray(window, jnp.int32).reshape(1)
    interpret = jax.default_backend() == "cpu"
    out = flash_attention_padded(qp, kp, vp, w, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret)
    return out[:, :S] if pad else out
