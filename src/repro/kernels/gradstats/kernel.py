"""Fused gradient-moment reduction Pallas kernel — TPU target.

This is the hot loop of the paper's adaptive batching: every outer step,
the norm / inner-product tests need per-sample statistics over the
(B, D) matrix of flattened per-sample gradients (D = model dim, huge;
B = probe batch).  The naive jnp formulation reads G three times
(mean, row-norms, G@ḡ).  The kernel computes

    colsum_j = Σ_i G_ij          (pass 1 — for ḡ)
    s_i = Σ_j G_ij²,  d_i = Σ_j G_ij · ḡ_j     (pass 2, fused)

so G streams HBM→VMEM exactly twice (once per pass) instead of three
times, with f32 accumulators in VMEM.

Layout: grid = (D/BD, B/BB) with the row axis sequential; each step
loads a (BB, BD) tile.  BD = 512 lanes amortizes the per-tile overhead;
accumulators: colsum (BD,), s/d (BB,) revisited across the D axis via
output-block accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _colsum_kernel(g_ref, out_ref, *, bb: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)                  # (BB, BD)
    out_ref[...] += jnp.sum(g, axis=0)


def _moments_kernel(g_ref, gbar_ref, s_ref, d_ref, *, bd: int):
    jd = pl.program_id(1)

    @pl.when(jd == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        d_ref[...] = jnp.zeros_like(d_ref)

    g = g_ref[...].astype(jnp.float32)                  # (BB, BD)
    gbar = gbar_ref[...].astype(jnp.float32)            # (BD,)
    s_ref[...] += jnp.sum(g * g, axis=1)
    d_ref[...] += g @ gbar


@functools.partial(jax.jit, static_argnames=("bb", "bd", "interpret"))
def gradstats_padded(G, *, bb: int = 8, bd: int = 512,
                     interpret: bool = True):
    """G: (B, D) with B % bb == 0, D % bd == 0.
    Returns (s (B,), d (B,), n2 (), b ())."""
    B, D = G.shape
    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, bb=bb),
        grid=(D // bd, B // bb),
        in_specs=[pl.BlockSpec((bb, bd), lambda jd, ib: (ib, jd))],
        out_specs=pl.BlockSpec((bd,), lambda jd, ib: (jd,)),
        out_shape=jax.ShapeDtypeStruct((D,), jnp.float32),
        interpret=interpret,
    )(G)
    gbar = colsum / B
    s, d = pl.pallas_call(
        functools.partial(_moments_kernel, bd=bd),
        grid=(B // bb, D // bd),
        in_specs=[
            pl.BlockSpec((bb, bd), lambda ib, jd: (ib, jd)),
            pl.BlockSpec((bd,), lambda ib, jd: (jd,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda ib, jd: (ib,)),
            pl.BlockSpec((bb,), lambda ib, jd: (ib,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        interpret=interpret,
    )(G, gbar)
    n2 = jnp.sum(jnp.square(gbar))
    return s, d, n2, jnp.float32(B)
