"""Pure-jnp oracle for the gradstats reduction.

Given G (B, D) per-sample gradients, returns
  s (B,)  = per-row squared norms  ||g_i||²
  d (B,)  = per-row inner products <g_i, ḡ>
  n2 ()   = ||ḡ||²
  b ()    = f32 row count
"""
from __future__ import annotations

import jax.numpy as jnp


def gradstats_reduce_ref(G: jnp.ndarray):
    G = G.astype(jnp.float32)
    gbar = jnp.mean(G, axis=0)
    s = jnp.sum(jnp.square(G), axis=1)
    d = G @ gbar
    n2 = jnp.sum(jnp.square(gbar))
    return s, d, n2, jnp.float32(G.shape[0])
