"""jit'd public wrapper for the gradstats reduction (padding + interpret
fallback).  Zero-padding is exact for all four outputs: padded rows
contribute 0 to colsum and produce s=d=0 entries that are sliced off;
the mean ḡ divides by the *true* B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gradstats.kernel import gradstats_padded


def gradstats_reduce(G, *, bb: int = 8, bd: int = 512):
    """G (B, D) -> (s (B,), d (B,), n2 (), b ()).  See core.batching."""
    B, D = G.shape
    bb = min(bb, B)
    bd = min(bd, max(128, D))
    pad_b = (-B) % bb
    pad_d = (-D) % bd
    Gp = jnp.pad(G, ((0, pad_b), (0, pad_d))) if (pad_b or pad_d) else G
    interpret = jax.default_backend() == "cpu"
    s, d, n2, _ = _stats_fixed_b(Gp, B, bb=bb, bd=bd, interpret=interpret)
    return s[:B], d[:B], n2, jnp.float32(B)


def _stats_fixed_b(Gp, true_b, *, bb, bd, interpret):
    # gradstats_padded divides colsum by padded B; rescale ḡ-dependent
    # outputs to the true row count.
    s, d, n2, _ = gradstats_padded(Gp, bb=bb, bd=bd, interpret=interpret)
    scale = Gp.shape[0] / true_b
    return s, d * scale, n2 * scale * scale, jnp.float32(true_b)
