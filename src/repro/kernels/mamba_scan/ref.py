"""Pure-jnp oracle for the selective scan (chunked associative scan)."""
from __future__ import annotations

from repro.models.layers import ssm_scan_chunked


def mamba_scan_ref(u, dt, A_log, Bm, Cm):
    """u, dt (B,S,di); A_log (di,n); Bm, Cm (B,S,n) ->
    (y (B,S,di), h_last (B,di,n))."""
    return ssm_scan_chunked(u, dt, A_log, Bm, Cm)
