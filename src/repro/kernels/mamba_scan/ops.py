"""jit'd public wrapper for the Mamba selective-scan kernel: padding to
block multiples + CPU interpret fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import mamba_scan_padded


def mamba_scan(u, dt, A_log, Bm, Cm, *, chunk: int = 128, bd: int = 128):
    """u, dt (B,S,di); A_log (di,n); Bm, Cm (B,S,n) ->
    (y (B,S,di), h_last (B,di,n))."""
    B, S, di = u.shape
    chunk = min(chunk, max(8, S))
    bd = min(bd, di)
    pad_s = (-S) % chunk
    pad_d = (-di) % bd
    neg_A = -jnp.exp(A_log.astype(jnp.float32))
    if pad_s or pad_d:
        pd = ((0, 0), (0, pad_s), (0, pad_d))
        u = jnp.pad(u, pd)
        dt = jnp.pad(dt, pd)
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_s), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_s), (0, 0)))
        neg_A = jnp.pad(neg_A, ((0, pad_d), (0, 0)))
    interpret = jax.default_backend() == "cpu"
    y, h_last = mamba_scan_padded(u, dt, neg_A, Bm, Cm, chunk=chunk, bd=bd,
                                  interpret=interpret)
    if pad_s or pad_d:
        y = y[:, :S, :di]
        h_last = h_last[:, :di]
    return y, h_last
