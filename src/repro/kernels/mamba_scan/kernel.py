"""Selective-scan (Mamba-1) Pallas kernel — TPU target.

Recurrence:  h_t = exp(dt_t ⊙ A) · h_{t-1} + (dt_t u_t) ⊗ B_t
             y_t = <h_t, C_t>  (contraction over the state dim n)

TPU-native layout (vs. the CUDA warp-parallel original):
  * grid = (batch, d_inner/BD, S/CHUNK); the chunk axis is sequential and
    carries the (BD, n) state h in VMEM scratch — the HBM→VMEM pipeline
    streams u/dt/B/C chunk-by-chunk while the recurrence stays resident.
  * BD = 128 puts d_inner on the sublane-tiled axis; the state dim n=16
    rides the lanes.  Per-chunk VMEM: 2·CHUNK·BD (u,dt) + 2·CHUNK·n
    (B,C) + BD·n (h) floats ≈ 0.26 MB at CHUNK=128.
  * the within-chunk loop is a fori_loop over time steps; each step is a
    (BD,n) fused multiply-add on the VPU — the op is memory-bound, so
    VMEM residency (not MXU utilization) is the roofline lever.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, dt_ref, nA_ref, b_ref, c_ref, y_ref, hout_ref,
                 h_ref, *, chunk: int, bd: int, n: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    nA = nA_ref[0, :, :].astype(jnp.float32)            # (BD, n), = -exp(A_log)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (BD,)
        u_t = u_ref[0, t, :].astype(jnp.float32)        # (BD,)
        b_t = b_ref[0, t, :].astype(jnp.float32)        # (n,)
        c_t = c_ref[0, t, :].astype(jnp.float32)        # (n,)
        a = jnp.exp(dt_t[:, None] * nA)                 # (BD, n)
        h = a * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)         # (BD,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == nc - 1)
    def _final():
        hout_ref[0, :, :] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "bd", "interpret"))
def mamba_scan_padded(u, dt, neg_A, Bm, Cm, *, chunk: int = 128,
                      bd: int = 128, interpret: bool = True):
    """u, dt: (B,S,di); neg_A: (di,n) = -exp(A_log); Bm, Cm: (B,S,n).
    S % chunk == 0, di % bd == 0.  Returns (y (B,S,di), h_last (B,di,n))."""
    B, S, di = u.shape
    n = neg_A.shape[1]
    grid = (B, di // bd, S // chunk)
    kernel = functools.partial(_scan_kernel, chunk=chunk, bd=bd, n=n)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, j, ic: (b, ic, j)),
            pl.BlockSpec((1, chunk, bd), lambda b, j, ic: (b, ic, j)),
            pl.BlockSpec((1, bd, n), lambda b, j, ic: (0, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j, ic: (b, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, j, ic: (b, ic, j)),
            pl.BlockSpec((1, bd, n), lambda b, j, ic: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(u.shape, u.dtype),
            jax.ShapeDtypeStruct((B, di, n), u.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, neg_A[None], Bm, Cm)
    return y, h_last
