"""ShapeDtypeStruct input specs + sharding plans for the dry-run.

``input_specs(cfg, shape)`` returns abstract stand-ins for every model
input (no device allocation), and the ``*_shardings`` helpers return the
matching NamedShardings for a given mesh.  The same functions drive the
real launcher, which feeds concrete arrays with identical layouts.

Sharding plan summary (baseline — §Perf iterates on this):
  train    batch (1, GB, S):        (None, data-axes, None)
  prefill  tokens (GB, S):          (data-axes, None)
  decode   token (GB,):             (data-axes,)
           kv cache (L,B,C,Hk,hd):  sequence-parallel cache — C sharded
             over "model" (B over data-axes), so decode attention's
             softmax/contraction run distributed over the cache length;
             when B < |data| (long_500k: B=1) the cache/state dims take
             the combined (data,model) axes instead.
  mamba state (L,B,di,n):           di sharded (model or data+model)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import LONG_CONTEXT_ARCHS
from repro.configs.base import InputShape, ModelConfig


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_size(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


# ------------------------------------------------------------------
# abstract inputs
# ------------------------------------------------------------------

def train_inputs(cfg: ModelConfig, shape: InputShape, accum: int = 1
                 ) -> Dict[str, Any]:
    GB, S = shape.global_batch, shape.seq_len
    assert GB % accum == 0
    mb = GB // accum
    batch = {"tokens": jax.ShapeDtypeStruct((accum, mb, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (accum, mb, cfg.num_prefix_tokens, cfg.d_model), _dt(cfg))
    elif cfg.frontend is not None:
        batch["prefix_emb"] = jax.ShapeDtypeStruct(
            (accum, mb, cfg.num_prefix_tokens, cfg.d_model), _dt(cfg))
    return batch


def prefill_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    GB, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((GB, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.ShapeDtypeStruct(
            (GB, cfg.num_prefix_tokens, cfg.d_model), _dt(cfg))
    elif cfg.frontend is not None:
        batch["prefix_emb"] = jax.ShapeDtypeStruct(
            (GB, cfg.num_prefix_tokens, cfg.d_model), _dt(cfg))
    return batch


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """long_500k uses the sub-quadratic path: ring buffer of window size
    (sliding-window archs) or pure state (SSM)."""
    if shape.name == "long_500k":
        assert cfg.name in LONG_CONTEXT_ARCHS or cfg.arch_type == "ssm", (
            f"{cfg.name} has no sub-quadratic path for long_500k "
            "(skip documented in DESIGN.md)")
        if cfg.sliding_window is not None:
            return cfg.sliding_window
        return 1  # attention-free: k/v cache unused
    return shape.seq_len


def decode_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract (token, pos, cache) for serve_step."""
    GB = shape.global_batch
    C = cache_len_for(cfg, shape)
    Ln = cfg.num_layers
    hd = cfg.resolved_head_dim
    dt = _dt(cfg)
    cache = {}
    if cfg.arch_type != "ssm":
        cache["k"] = jax.ShapeDtypeStruct((Ln, GB, C, cfg.num_kv_heads, hd), dt)
        cache["v"] = jax.ShapeDtypeStruct((Ln, GB, C, cfg.num_kv_heads, hd), dt)
    if cfg.arch_type == "ssm" or cfg.hybrid:
        cache["conv"] = jax.ShapeDtypeStruct(
            (Ln, GB, cfg.ssm.conv_dim - 1, cfg.d_inner), dt)
        cache["ssm"] = jax.ShapeDtypeStruct(
            (Ln, GB, cfg.d_inner, cfg.ssm.state_dim), dt)
    if cfg.is_encoder_decoder:
        cache["xk"] = jax.ShapeDtypeStruct(
            (Ln, GB, cfg.num_prefix_tokens, cfg.num_kv_heads, hd), dt)
        cache["xv"] = jax.ShapeDtypeStruct(
            (Ln, GB, cfg.num_prefix_tokens, cfg.num_kv_heads, hd), dt)
    return {
        "token": jax.ShapeDtypeStruct((GB,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def abstract_params(cfg: ModelConfig):
    from repro import models
    return jax.eval_shape(
        lambda k: models.init_params(cfg, k), jax.random.PRNGKey(0))


# ------------------------------------------------------------------
# shardings
# ------------------------------------------------------------------

def train_batch_shardings(batch, mesh: Mesh):
    da = data_axes(mesh)

    def spec(leaf):
        return NamedSharding(mesh, P(None, da, *([None] * (leaf.ndim - 2))))

    return jax.tree.map(spec, batch)


def prefill_batch_shardings(batch, mesh: Mesh):
    da = data_axes(mesh)

    def spec(leaf):
        return NamedSharding(mesh, P(da, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(spec, batch)


def decode_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """(token_sh, pos_sh, cache_sh) — see module docstring."""
    da = data_axes(mesh)
    GB = shape.global_batch
    wide_batch = GB % data_size(mesh) == 0 and GB >= data_size(mesh)
    if wide_batch:
        b_ax, feat_ax = da, ("model",)
        tok = P(da)
    else:
        # tiny batch (long_500k): replicate B, spread features/cache over
        # every axis we have
        b_ax, feat_ax = None, da + ("model",)
        tok = P()

    def ns(*parts):
        return NamedSharding(mesh, P(*parts))

    def _axsize(ax) -> int:
        import math
        if ax is None:
            return 1
        axes = (ax,) if isinstance(ax, str) else ax
        return math.prod(mesh.shape[a] for a in axes)

    def pick(dim: int, ax):
        """feat_ax if it divides, else progressively smaller fallbacks."""
        for cand in (ax, ("model",), None):
            if dim % _axsize(cand) == 0:
                return cand
        return None

    C = cache_len_for(cfg, shape)
    cache_specs = {}
    if cfg.arch_type != "ssm":
        # (L, B, C, Hk, hd): sequence-parallel over C
        cache_specs["k"] = ns(None, b_ax, pick(C, feat_ax), None, None)
        cache_specs["v"] = ns(None, b_ax, pick(C, feat_ax), None, None)
    if cfg.arch_type == "ssm" or cfg.hybrid:
        di = cfg.d_inner
        cache_specs["conv"] = ns(None, b_ax, None, pick(di, feat_ax))
        cache_specs["ssm"] = ns(None, b_ax, pick(di, feat_ax), None)
    if cfg.is_encoder_decoder:
        # cross-attn cache: frame count (1500) is rarely divisible by the
        # mesh — shard head_dim over "model" instead
        hd_ok = cfg.resolved_head_dim % mesh.shape.get("model", 1) == 0
        hd_ax = "model" if hd_ok else None
        cache_specs["xk"] = ns(None, b_ax, None, None, hd_ax)
        cache_specs["xv"] = ns(None, b_ax, None, None, hd_ax)
    return (NamedSharding(mesh, tok), NamedSharding(mesh, P()), cache_specs)
