"""Launchers: mesh, dryrun, train, specs, hlo_analysis, roofline."""
