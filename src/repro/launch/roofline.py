"""Roofline analysis over the dry-run artifacts.

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``)
and derives, per (arch x shape x mesh), the three roofline terms for the
TPU v5e target:

  compute term    = HLO_FLOPs_per_chip   / peak_FLOP/s
  memory term     = HLO_bytes_per_chip   / HBM_bw
  collective term = wire_bytes_per_chip  / link_bw

All artifact numbers are already per-chip (post-SPMD partitioned HLO,
trip-count corrected by ``hlo_analysis``).  Additionally reports
MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode) with N = active
params, the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, the dominant
term, and a one-line "what would move it" note.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline                # table
  PYTHONPATH=src python -m repro.launch.roofline --markdown     # for EXPERIMENTS.md
  PYTHONPATH=src python -m repro.launch.roofline --csv
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import ARCH_REGISTRY, INPUT_SHAPES, get_config

# ---- TPU v5e-class hardware constants (per system assignment) ----------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    accum: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # per-chip useful model FLOPs
    hlo_flops: float            # per-chip compiled FLOPs
    bound_s: float              # max of the three = roofline step time
    dominant: str
    useful_ratio: float
    note: str
    raw: dict

    @property
    def roofline_fraction(self) -> float:
        """useful compute time / roofline-bound step time (an MFU-like
        number: 1.0 would be 'every cycle does a useful model FLOP')."""
        if self.bound_s <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.bound_s


def _chips(mesh_name: str) -> int:
    return {"pod16x16": 256, "pod2x16x16": 512}.get(mesh_name, 256)


def model_flops_per_chip(arch: str, shape_name: str, chips: int,
                         accum: int = 1) -> float:
    """6*N*D train / 2*N*D forward, N = active params, D = tokens,
    divided by chip count (data/model parallel split is irrelevant to
    the aggregate useful-FLOP budget)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * accum
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            # enc-dec "prefill" = encode frames + ONE decode step, not a
            # seq_len-token decoder pass (whisper: 1500 frames)
            tokens = shape.global_batch * (cfg.num_prefix_tokens + 1)
        else:
            tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per request
        tokens = shape.global_batch
        total = 2.0 * n_active * tokens
    return total / chips


def _suggestion(dominant: str, row: dict, arch: str, shape: str) -> str:
    cfg = ARCH_REGISTRY[arch]
    per = row.get("per_collective", {})
    big = max(per, key=per.get) if per else ""
    if dominant == "collective":
        if big == "all-gather":
            return ("all-gather dominates: overlap weight gathers with "
                    "compute or shrink FSDP axis / batch the gathers")
        if big == "all-reduce":
            return ("grad all-reduce dominates: reduce-scatter + local "
                    "update (ZeRO) or accumulate more before syncing "
                    "(AdLoCo's own lever)")
        return f"{big} dominates: reschedule/overlap it"
    if dominant == "memory":
        if shape.startswith("decode"):
            return ("KV-cache streaming bound (expected for 1-token "
                    "decode): bigger per-chip batch or quantized cache")
        return ("HBM bound: fuse elementwise chains, cut remat, or "
                "raise per-chip arithmetic intensity (bigger microbatch)")
    if cfg.arch_type == "moe":
        return "compute bound (good): MXU-align expert matmuls"
    return "compute bound (good): already near the useful-FLOP roof"


def load_rows(art_dir: str = ART_DIR) -> List[RooflineRow]:
    rows: List[RooflineRow] = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("status") != "ok" or r.get("shape") == "adloco_outer":
            continue
        chips = _chips(r["mesh"])
        accum = int(r.get("accum", 1))
        c_s = r["flops"] / PEAK_FLOPS
        m_s = r["bytes_accessed"] / HBM_BW
        k_s = r["collective_wire_bytes"] / LINK_BW
        mf = model_flops_per_chip(r["arch"], r["shape"], chips, accum)
        terms = {"compute": c_s, "memory": m_s, "collective": k_s}
        dominant = max(terms, key=terms.get)
        rows.append(RooflineRow(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], accum=accum,
            compute_s=c_s, memory_s=m_s, collective_s=k_s,
            model_flops=mf, hlo_flops=r["flops"],
            bound_s=max(terms.values()), dominant=dominant,
            useful_ratio=mf / max(r["flops"], 1.0),
            note=_suggestion(dominant, r, r["arch"], r["shape"]),
            raw=r))
    return rows


def baseline_rows(rows: List[RooflineRow]) -> List[RooflineRow]:
    """accum==1 single+multi pod rows (the 40-pair baseline table)."""
    return [r for r in rows if r.accum == 1]


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:7.2f}ms"
    return f"{x * 1e6:7.1f}us"


def print_table(rows: List[RooflineRow], markdown: bool = False) -> None:
    if markdown:
        print("| arch | shape | mesh | compute | memory | collective | "
              "bound | dominant | MFLOPs/HLO | roofline frac |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r.arch} | {r.shape} | {r.mesh} | "
                  f"{fmt_s(r.compute_s).strip()} | {fmt_s(r.memory_s).strip()} | "
                  f"{fmt_s(r.collective_s).strip()} | {fmt_s(r.bound_s).strip()} | "
                  f"**{r.dominant}** | {r.useful_ratio:.2f} | "
                  f"{r.roofline_fraction:.2f} |")
        return
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':11s} {'compute':9s} "
           f"{'memory':9s} {'collect':9s} {'dominant':10s} "
           f"{'useful':7s} {'rooffrac':8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r.arch:22s} {r.shape:12s} {r.mesh:11s} "
              f"{fmt_s(r.compute_s)} {fmt_s(r.memory_s)} "
              f"{fmt_s(r.collective_s)} {r.dominant:10s} "
              f"{r.useful_ratio:6.2f}  {r.roofline_fraction:6.2f}")


def pick_hillclimb_pairs(rows: List[RooflineRow]) -> Dict[str, RooflineRow]:
    """worst roofline fraction / most collective-bound / most
    representative of the paper's technique.

    decode shapes are excluded from 'worst': a 1-token step is
    structurally memory-bound (stream the whole KV cache for one MAC per
    byte) and offers no hillclimb story beyond 'batch more requests'.
    """
    single = [r for r in rows if r.mesh == "pod16x16" and r.accum == 1]
    big = [r for r in single if r.shape in ("train_4k", "prefill_32k")]
    worst = min(big, key=lambda r: r.roofline_fraction)
    coll = max((r for r in big if r is not worst),
               key=lambda r: r.collective_s /
               max(r.compute_s, r.memory_s, 1e-12))
    train = [r for r in single if r.shape == "train_4k"
             and r is not worst and r is not coll]
    # paper's technique targets the *training* outer-sync collective;
    # the most representative pair is the biggest train config, where
    # every outer sync moves the most bytes and adaptive batching's
    # O(ln N) communication law has the most to save.
    rep = max(train, key=lambda r: r.raw.get("params", 0))
    return {"worst_roofline": worst, "most_collective": coll,
            "paper_representative": rep}


def inject_experiments(path: str) -> None:
    """Replace the <!-- ROOFLINE_TABLE --> marker (or previously injected
    block) in EXPERIMENTS.md with the current markdown table."""
    import io
    import re as _re
    rows = baseline_rows(load_rows())
    buf = io.StringIO()
    import contextlib
    with contextlib.redirect_stdout(buf):
        print("<!-- ROOFLINE_TABLE -->")
        print_table([r for r in rows if r.mesh == "pod16x16"],
                    markdown=True)
        print()
        print("Multi-pod (2×16×16, 512 chips) — proves the pod axis "
              "shards; terms are per chip:")
        print()
        print_table([r for r in rows if r.mesh == "pod2x16x16"],
                    markdown=True)
        print("<!-- /ROOFLINE_TABLE -->")
    with open(path) as f:
        text = f.read()
    block = buf.getvalue()
    if "<!-- /ROOFLINE_TABLE -->" in text:
        text = _re.sub(
            r"<!-- ROOFLINE_TABLE -->.*?<!-- /ROOFLINE_TABLE -->",
            lambda _: block.rstrip(), text, flags=_re.S)
    else:
        text = text.replace("<!-- ROOFLINE_TABLE -->", block.rstrip())
    with open(path, "w") as f:
        f.write(text)
    print(f"[roofline] table injected -> {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--mesh", default=None,
                    choices=["pod16x16", "pod2x16x16"])
    ap.add_argument("--pick", action="store_true",
                    help="print the three hillclimb pairs")
    ap.add_argument("--inject", default=None, metavar="EXPERIMENTS_MD",
                    help="write the table into EXPERIMENTS.md in place")
    args = ap.parse_args(argv)
    if args.inject:
        inject_experiments(args.inject)
        return 0
    rows = baseline_rows(load_rows())
    if args.mesh:
        rows = [r for r in rows if r.mesh == args.mesh]
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,roofline_fraction")
        for r in rows:
            print(f"{r.arch},{r.shape},{r.mesh},{r.compute_s:.6g},"
                  f"{r.memory_s:.6g},{r.collective_s:.6g},{r.dominant},"
                  f"{r.useful_ratio:.4f},{r.roofline_fraction:.4f}")
    else:
        print_table(rows, markdown=args.markdown)
    if args.pick:
        picks = pick_hillclimb_pairs(load_rows())
        print()
        for why, r in picks.items():
            print(f"[pick] {why:22s} -> {r.arch} x {r.shape} "
                  f"(dominant={r.dominant}, frac={r.roofline_fraction:.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
