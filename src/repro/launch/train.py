"""Training launcher: AdLoCo on a real device mesh (or the host CPU for
demos/smoke runs).

  PYTHONPATH=src python -m repro.launch.train --arch microllama-300m \\
      --outer-steps 4 --inner-steps 8 --trainers 2 --workers 2 \\
      --seq-len 128 --reduced

On a TPU pod each trainer instance occupies its own mesh slice (the
"pod" axis of launch/mesh.py); here the trainer pool is orchestrated
host-side over jitted steps — identical semantics, metered comms.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro import models
from repro.configs import ARCH_REGISTRY, get_config, reduced
from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco
from repro.checkpoint import save_train_state
from repro.data import make_shard_streams


def build_loss_fn(cfg, *, logit_chunk=None):
    def loss_fn(params, batch):
        return models.loss_fn(params, batch, cfg, logit_chunk=logit_chunk)
    return loss_fn


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="microllama-300m",
                    choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer reduced variant (CPU-friendly)")
    ap.add_argument("--outer-steps", type=int, default=4)
    ap.add_argument("--inner-steps", type=int, default=8)
    ap.add_argument("--trainers", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--initial-batch", type=int, default=2)
    ap.add_argument("--lr-inner", type=float, default=3e-4)
    ap.add_argument("--lr-outer", type=float, default=0.5)
    ap.add_argument("--eta", type=float, default=0.8)
    ap.add_argument("--batch-test", default="norm",
                    choices=["norm", "inner_product", "augmented"])
    ap.add_argument("--no-adaptive", action="store_true")
    ap.add_argument("--no-merge", action="store_true")
    ap.add_argument("--no-switch", action="store_true")
    ap.add_argument("--merge-frequency", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir "
                         "before training")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    acfg = AdLoCoConfig(
        num_outer_steps=args.outer_steps,
        num_inner_steps=args.inner_steps,
        lr_inner=args.lr_inner,
        lr_outer=args.lr_outer,
        num_init_trainers=args.trainers,
        nodes_per_gpu=args.workers,
        initial_batch_size=args.initial_batch,
        merge_frequency=args.merge_frequency,
        eta=args.eta,
        max_batch=args.max_batch,
        batch_test=args.batch_test,
        adaptive=not args.no_adaptive,
        enable_merge=not args.no_merge,
        enable_switch=not args.no_switch,
        seed=args.seed,
    )

    k, M = acfg.num_init_trainers, acfg.nodes_per_gpu
    keys = jax.random.split(jax.random.PRNGKey(acfg.seed), k)
    init_params = [models.init_params(cfg, kk) for kk in keys]
    streams = make_shard_streams(cfg.vocab_size, args.seq_len, k * M,
                                 seed=acfg.seed)
    loss_fn = build_loss_fn(cfg)

    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"k={k} M={M} H={acfg.num_inner_steps} T={acfg.num_outer_steps}")

    restore_from = None
    if args.resume and args.ckpt_dir:
        from repro.checkpoint import latest_step
        step = latest_step(args.ckpt_dir)
        if step is not None:
            restore_from = (args.ckpt_dir, step)
            print(f"[train] resuming from {args.ckpt_dir} step {step}")

    pool, hist = train_adloco(loss_fn, init_params, streams, acfg,
                              verbose=True, restore_from=restore_from)
    print(f"[train] final loss={hist.loss[-1]:.4f} "
          f"comm_events={pool.comms.events} "
          f"comm_GB={pool.comms.total_bytes/2**30:.3f}")
    if args.ckpt_dir:
        save_train_state(args.ckpt_dir, acfg.num_outer_steps, pool)
        print(f"[train] checkpoint -> {args.ckpt_dir}")
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(hist.as_dict(), f, indent=2)
        print(f"[train] history -> {args.history_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
