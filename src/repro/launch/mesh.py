"""Production mesh definitions.

A TPU v5e pod slice of 256 chips is modeled as a (16, 16) mesh with axes
("data", "model"); the two-pod production deployment is (2, 16, 16) with
axes ("pod", "data", "model").

In the AdLoCo deployment the "pod" axis doubles as the *trainer-instance*
axis: inner DiLoCo steps all-reduce gradients over "data" only (ICI-local
within a pod), while the outer synchronization / trainer merging are the
only collectives that cross "pod" (DCI).  See launch/dryrun.py.

These are FUNCTIONS, not module constants — importing this module never
touches jax device state (device count is locked at first jax init, and
the 512-device XLA_FLAGS override belongs to dryrun.py alone).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU smoke tests, examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~uni-directional)
VMEM_BYTES = 16 * 2 ** 20
HBM_BYTES = 16 * 2 ** 30
