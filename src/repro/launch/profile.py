import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run profiler: lower one (arch x shape x mesh), print the
trip-count-scaled top cost centers and loop structure — the 'profile'
the §Perf hillclimb iterates against (no real TPU in this container).

  PYTHONPATH=src python -m repro.launch.profile --arch falcon-mamba-7b \
      --shape prefill_32k [--multipod] [--top 30] [--dump hlo.txt]
"""
import argparse
import sys

from repro.configs import ARCH_REGISTRY, INPUT_SHAPES, get_config
from repro.launch import hlo_analysis
from repro.launch.dryrun import (lower_decode, lower_prefill, lower_train,
                                 make_production_mesh)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--dump", default=None, help="write full HLO here")
    ap.add_argument("--compiled", action="store_true",
                    help="profile post-optimization HLO (compile first; "
                         "slower but matches the roofline artifacts)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multipod)
    if shape.kind == "train":
        lowered = lower_train(cfg, shape, mesh, args.accum)
    elif shape.kind == "prefill":
        lowered = lower_prefill(cfg, shape, mesh)
    else:
        lowered = lower_decode(cfg, shape, mesh)
    hlo = lowered.compile().as_text() if args.compiled \
        else lowered.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(hlo)
        print(f"[profile] HLO -> {args.dump} ({len(hlo) / 1e6:.1f} MB)")
    print(hlo_analysis.profile(hlo, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
