import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination with abstract inputs, and record memory / cost /
collective analysis for the roofline.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and only the dry-run wants 512
placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full sweep
  PYTHONPATH=src python -m repro.launch.dryrun --all --multipod # + 2-pod pass

Per combination this lowers:
  train_4k              -> train_step  (DiLoCo inner step: fwd+bwd+AdamW,
                                        SwitchMode accumulation scan)
  prefill_32k           -> prefill_step (KV-cache fill, last-token logits)
  decode_32k, long_500k -> serve_step  (1 token vs seq_len KV cache)
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models, optim
from repro.configs import (ARCH_REGISTRY, ASSIGNED_ARCHS, INPUT_SHAPES,
                           LONG_CONTEXT_ARCHS, get_config)
from repro.core.diloco import make_inner_step_fn
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro import sharding as shard_rules
from repro.launch import hlo_analysis

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# REPRO_BASELINE=1 lowers the paper-faithful baseline configuration
# (no activation-sharding constraints, full-sequence prefill logits) so
# §Perf before/after numbers come from the same code + analyzer.
BASELINE = os.environ.get("REPRO_BASELINE", "") == "1"


def _policy(mesh):
    import contextlib
    if BASELINE:
        return contextlib.nullcontext()
    return shard_rules.activation_policy(
        S.data_axes(mesh), model_size=mesh.shape.get("model", 0))

COLLECTIVE_RE = re.compile(
    r"=\s+(\S+?)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4,
                "u16": 2, "s16": 2, "u8": 1, "s8": 1, "pred": 1, "f8e4m3": 1,
                "f8e5m2": 1, "u64": 8, "s64": 8}

# ring all-reduce moves ~2x the payload per participant; one-shot
# gather/scatter/permute move ~1x.
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective in the (post-SPMD,
    per-device) HLO.  Returns totals per collective kind plus a wire-byte
    estimate (ring factor applied)."""
    per_kind: dict = {}
    wire = 0.0
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if m.group(0).count("-done(") and kind != "collective-permute":
            continue  # async pairs: count the -start only
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        wire += _WIRE_FACTOR[kind] * nbytes
    per_kind["wire_bytes"] = wire
    return per_kind


def big_archs():
    """Archs whose optimizer state needs ZeRO/FSDP sharding to fit."""
    return {name for name, cfg in ARCH_REGISTRY.items()
            if cfg.param_count() > 5e9}


def make_train_step(cfg, accum: int):
    opt = optim.adamw(2e-5, weight_decay=0.1)

    def loss(params, mb):
        return models.loss_fn(params, mb, cfg, logit_chunk=512)

    return make_inner_step_fn(loss, opt, accum), opt


def lower_train(cfg, shape, mesh, accum: int = 1):
    fsdp = cfg.name in big_archs()
    step_fn, opt = make_train_step(cfg, accum)
    a_params = S.abstract_params(cfg)
    a_opt = jax.eval_shape(opt.init, a_params)
    a_batch = S.train_inputs(cfg, shape, accum)
    p_sh = shard_rules.param_shardings(a_params, mesh, fsdp=fsdp)
    o_sh = shard_rules.opt_state_shardings(a_opt, mesh, fsdp=fsdp)
    b_sh = S.train_batch_shardings(a_batch, mesh)
    loss_sh = NamedSharding(mesh, P())
    jitted = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, loss_sh, p_sh),
        donate_argnums=(0, 1),
    )
    with mesh, _policy(mesh):
        return jitted.lower(a_params, a_opt, a_batch)


def make_prefill_step(cfg, shape):
    C = S.cache_len_for(cfg, shape)

    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            from repro.models import encdec
            cache = encdec.init_cache(cfg, params, batch["frames"], C)
            logits, cache = encdec.decode_step(
                params, cache, batch["tokens"][:, 0], jnp.int32(0), cfg)
            return logits, cache
        logits, cache = models.prefill(
            params, batch["tokens"], cfg, C,
            prefix_emb=batch.get("prefix_emb"), last_only=not BASELINE)
        return logits[:, -1], cache

    return prefill_step


def lower_prefill(cfg, shape, mesh):
    fsdp = cfg.name in big_archs()
    step_fn = make_prefill_step(cfg, shape)
    a_params = S.abstract_params(cfg)
    a_batch = S.prefill_inputs(cfg, shape)
    p_sh = shard_rules.param_shardings(a_params, mesh, fsdp=fsdp)
    b_sh = S.prefill_batch_shardings(a_batch, mesh)
    jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
    with mesh, _policy(mesh):
        return jitted.lower(a_params, a_batch)


def lower_decode(cfg, shape, mesh):
    fsdp = cfg.name in big_archs()

    def serve_step(params, cache, token, pos):
        return models.decode_step(params, cache, token, pos, cfg)

    a_params = S.abstract_params(cfg)
    dec = S.decode_inputs(cfg, shape)
    p_sh = shard_rules.param_shardings(a_params, mesh, fsdp=fsdp)
    tok_sh, pos_sh, cache_sh = S.decode_shardings(cfg, shape, mesh)
    jitted = jax.jit(
        serve_step,
        in_shardings=(p_sh, cache_sh, tok_sh, pos_sh),
        donate_argnums=(1,),
    )
    with mesh:
        return jitted.lower(a_params, dec["cache"], dec["token"], dec["pos"])


def lower_adloco_outer(cfg, mesh):
    """The paper's cross-instance collective schedule on the multi-pod
    mesh: each pod is one trainer instance (stacked leading axis sharded
    over "pod").  One program does the DiLoCo outer step — weighted
    pseudo-gradient average across instances + Nesterov — AND the MIT
    merge (batch-size-weighted parameter average, Algorithm 2).  All
    cross-pod traffic of AdLoCo lives in this program; inner steps never
    touch the pod axis."""
    assert "pod" in mesh.axis_names
    npod = mesh.shape["pod"]
    from repro import optim as O
    outer_opt = O.nesterov_outer(0.5, 0.9)

    def outer_and_merge(x_prev, instance_params, outer_state, weights):
        # pseudo-gradient per instance, weighted-averaged across "pod"
        w = weights / jnp.sum(weights)
        delta = jax.tree.map(
            lambda xp, xs: xp.astype(jnp.float32) - jnp.einsum(
                "p,p...->...", w, xs.astype(jnp.float32)),
            x_prev, instance_params)
        updates, outer_state = outer_opt.update(delta, outer_state, x_prev)
        x_new = O.apply_updates(x_prev, updates)
        return x_new, outer_state

    a_params = S.abstract_params(cfg)
    p_sh = shard_rules.param_shardings(a_params, mesh,
                                       fsdp=cfg.name in big_archs())
    stack = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((npod,) + l.shape, l.dtype), a_params)
    stack_sh = jax.tree.map(
        lambda s: NamedSharding(
            mesh, P(("pod",), *s.spec)), p_sh)
    a_outer = jax.eval_shape(outer_opt.init, a_params)
    o_sh = shard_rules.opt_state_shardings(a_outer, mesh,
                                           fsdp=cfg.name in big_archs())
    w_sh = NamedSharding(mesh, P())
    jitted = jax.jit(outer_and_merge,
                     in_shardings=(p_sh, stack_sh, o_sh, w_sh),
                     out_shardings=(p_sh, o_sh))
    with mesh:
        return jitted.lower(a_params, stack, a_outer,
                            jax.ShapeDtypeStruct((npod,), jnp.float32))


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              accum: int = 1, save: bool = True, verbose: bool = True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS \
            and cfg.arch_type != "ssm":
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "no sub-quadratic path (DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered = lower_train(cfg, shape, mesh, accum)
        elif shape.kind == "prefill":
            lowered = lower_prefill(cfg, shape, mesh)
        else:
            lowered = lower_decode(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        corrected = hlo_analysis.analyze(hlo)
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", "accum": accum,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            # XLA's numbers (while bodies counted once — recorded for
            # reference) and the trip-count-corrected per-device numbers
            # from repro.launch.hlo_analysis:
            "xla_flops": cost.get("flops", 0.0),
            "xla_bytes_accessed": cost.get("bytes accessed", 0.0),
            "flops": corrected["flops"],
            "bytes_accessed": corrected["bytes"],
            "collective_bytes": corrected["collective_bytes"],
            "collective_wire_bytes": corrected["collective_wire_bytes"],
            "per_collective": corrected["per_collective"],
            "collectives": coll,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
            },
            "params": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
        }
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a bug report
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
    if verbose:
        if result["status"] == "ok":
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:10s} OK "
                  f"flops/dev={result['flops']:.3e} "
                  f"bytes/dev={result['bytes_accessed']:.3e} "
                  f"temp={result['memory']['temp_bytes']/2**30:.2f}GiB "
                  f"coll={result['collective_wire_bytes']/2**30:.3f}GiB "
                  f"(compile {result['compile_s']}s)", flush=True)
        else:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:10s} "
                  f"{result['status'].upper()}: "
                  f"{result.get('reason', result.get('error'))}", flush=True)
    if save and result["status"] != "skipped":
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}"
                          + (f"__accum{accum}" if accum != 1 else "") + ".json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--shape", default=None, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true", help="sweep all combos")
    ap.add_argument("--multipod", action="store_true",
                    help="use the (2,16,16) two-pod mesh")
    ap.add_argument("--accum", type=int, default=1,
                    help="SwitchMode accumulation steps for train_4k")
    ap.add_argument("--adloco-outer", action="store_true",
                    help="lower the cross-instance outer+merge program "
                         "on the 2-pod mesh for every arch")
    args = ap.parse_args(argv)

    if args.adloco_outer:
        mesh = make_production_mesh(multi_pod=True)
        failures = 0
        os.makedirs(OUT_DIR, exist_ok=True)
        for arch in ASSIGNED_ARCHS:
            cfg = get_config(arch)
            t0 = time.time()
            try:
                lowered = lower_adloco_outer(cfg, mesh)
                compiled = lowered.compile()
                corrected = hlo_analysis.analyze(compiled.as_text())
                res = {"arch": arch, "shape": "adloco_outer",
                       "mesh": "pod2x16x16", "status": "ok",
                       "flops": corrected["flops"],
                       "bytes_accessed": corrected["bytes"],
                       "collective_bytes": corrected["collective_bytes"],
                       "collective_wire_bytes":
                           corrected["collective_wire_bytes"],
                       "per_collective": corrected["per_collective"],
                       "compile_s": round(time.time() - t0, 1),
                       "params": cfg.param_count()}
                print(f"[dryrun] {arch:22s} adloco_outer pod2x16x16 OK "
                      f"coll={res['collective_wire_bytes']/2**30:.3f}GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                res = {"arch": arch, "shape": "adloco_outer",
                       "status": "error", "error": str(e)[-500:]}
                failures += 1
                print(f"[dryrun] {arch:22s} adloco_outer ERROR {e}",
                      flush=True)
            with open(os.path.join(
                    OUT_DIR, f"{arch}__adloco_outer__pod2x16x16.json"),
                    "w") as f:
                json.dump(res, f, indent=2)
        print(f"[dryrun] adloco-outer done, {failures} failures")
        return 1 if failures else 0

    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in combos:
        r = run_combo(arch, shape, multi_pod=args.multipod, accum=args.accum)
        failures += r["status"] == "error"
    print(f"[dryrun] done: {len(combos)} combos, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
