"""Trip-count-corrected cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, so a
scan-over-layers model under-reports FLOPs by ~the layer count.  This
module re-derives roofline inputs from ``compiled.as_text()``:

  * FLOPs: every ``dot`` contributes 2·|result|·|contracted dims|,
    recursively through fusions/calls, and while bodies are multiplied
    by their trip count (parsed from the loop-condition constant).
  * HBM bytes: post-fusion traffic model — each *top-level* op in a
    computation contributes |operands| + |result| bytes (a fusion is one
    unit: exactly its HBM reads/writes), while bodies × trip count.
    Parameters / constants / tuple plumbing are free.
  * Collective bytes: result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, × trip count when
    inside loop bodies, with a ring wire factor (2 for all-reduce).

The HLO here is the per-device partitioned module, so all numbers are
per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"([\w\-]+)\(")
_TUPLE_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(")
_OPERANDS = re.compile(r"\(((?:%?[\w.\-]+(?:,\s*)?)+)\)")
_CALLS = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "get-dimension-size", "iota"}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}
_WIRE_FACTOR = {"all-reduce": 2.0}


@dataclass
class Instr:
    name: str
    dtype: str
    dims: Tuple[int, ...]
    op: str
    line: str
    operands: List[str] = field(default_factory=list)

    @property
    def result_bytes(self) -> int:
        if self.dtype == "tuple":
            return 0
        b = _DTYPE_BYTES.get(self.dtype, 4)
        for d in self.dims:
            b *= d
        return b


@dataclass
class CostResult:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0          # payload (result-shape) bytes
    collective_wire_bytes: float = 0.0     # ring-model wire bytes
    per_collective: Dict[str, float] = field(default_factory=dict)

    def scaled(self, k: float) -> "CostResult":
        return CostResult(
            self.flops * k, self.bytes * k, self.collective_bytes * k,
            self.collective_wire_bytes * k,
            {kk: v * k for kk, v in self.per_collective.items()})

    def add(self, other: "CostResult") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        self.collective_wire_bytes += other.collective_wire_bytes
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.shape_of: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
        self._parse(text)
        self._memo: Dict[str, CostResult] = {}
        self._fusion_memo: Dict[str, float] = {}
        self._trip_memo: Dict[str, int] = {}
        self._slice_memo: Dict[str, bool] = {}
        self._dus_memo: Dict[str, Optional[Instr]] = {}

    # -------------------------------------------------------------- parse
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.startswith("HloModule"):
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    current = m.group(1)
                    self.computations[current] = []
                    continue
            if line.strip() == "}":
                continue
            m = _INSTR.match(line)
            if m and current is not None:
                name, dtype, dims_s, op = m.groups()
                dims = tuple(int(d) for d in dims_s.split(",") if d)
                ins = Instr(name, dtype, dims, op, line)
                self.computations[current].append(ins)
                self.shape_of[name] = (dtype, dims)
            elif _TUPLE_INSTR.match(line) and current is not None:
                # tuple-shaped result (while, all-reduce-start tuples...)
                tm = _TUPLE_INSTR.match(line)
                opm = re.search(r"\)\s+([\w\-]+)\(", line)
                op = opm.group(1) if opm else "tuple"
                ins = Instr(tm.group(1), "tuple", (), op, line)
                self.computations[current].append(ins)
                self.shape_of[tm.group(1)] = ("tuple", ())

    # -------------------------------------------------------------- sizes
    def _shape_bytes(self, name: str) -> int:
        dtype, dims = self.shape_of.get(name, ("tuple", ()))
        if dtype == "tuple":
            return 0
        b = _DTYPE_BYTES.get(dtype, 4)
        for d in dims:
            b *= d
        return b

    @staticmethod
    def _operand_names(line: str, op: Optional[str] = None) -> List[str]:
        # non-regex: take the parenthesized list right after "op(", with
        # depth counting.  Anchoring on the op name matters for
        # tuple-shaped results, where "= (f32[...], ...) all-reduce(...)"
        # would otherwise hand back the tuple TYPE list.
        eq = line.find("= ")
        if op is not None:
            anchor = line.find(op + "(", eq if eq >= 0 else 0)
            start = line.find("(", anchor) if anchor >= 0 else -1
        else:
            start = line.find("(", eq if eq >= 0 else 0)
        if start < 0:
            return []
        depth, i = 1, start + 1
        while i < len(line) and depth:
            c = line[i]
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
            i += 1
        inner = line[start + 1:i - 1]
        out = []
        for t in inner.split(","):
            t = t.strip()
            if not t:
                continue
            # tokens may be "%name" or "f32[2,3]{1,0} %name"
            name = t.split()[-1].lstrip("%")
            if name and (name[0].isalpha() or name[0] in "._"):
                out.append(name)
        return out

    # -------------------------------------------------------- trip counts
    def _trip_count(self, cond_name: str) -> int:
        """Max integer constant inside the loop condition (covers
        wrapped-fusion compares); 1 if none found (conservative)."""
        if cond_name in self._trip_memo:
            return self._trip_memo[cond_name]
        best = 0
        stack = [cond_name]
        seen = set()
        while stack:
            c = stack.pop()
            if c in seen or c not in self.computations:
                continue
            seen.add(c)
            for ins in self.computations[c]:
                for m in _CONSTANT.finditer(ins.line):
                    best = max(best, int(m.group(1)))
                cm = _CALLS.findall(ins.line)
                stack.extend(cm)
        best = max(best, 1)
        self._trip_memo[cond_name] = best
        return best

    # ---------------------------------------------------------- op costs
    def _dot_flops(self, ins: Instr) -> float:
        ops = self._operand_names(ins.line, ins.op)
        if not ops:
            return 0.0
        lhs_dtype, lhs_dims = self.shape_of.get(ops[0], ("f32", ()))
        m = _CONTRACT.search(ins.line)
        contract = 1
        if m:
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
        result = 1
        for d in ins.dims:
            result *= d
        return 2.0 * result * contract

    # ------------------------------------------------------- computation
    def computation_cost(self, name: str) -> CostResult:
        if name in self._memo:
            return self._memo[name]
        total = CostResult()
        for ins in self.computations.get(name, []):
            total.add(self._instr_cost(ins, top_level=True))
        self._memo[name] = total
        return total

    def _fusion_flops(self, name: str) -> float:
        """FLOPs inside a fusion/called computation (bytes NOT counted —
        the fusion is one HBM unit)."""
        if name in self._fusion_memo:
            return self._fusion_memo[name]
        self._fusion_memo[name] = 0.0      # cycle guard
        total = 0.0
        for ins in self.computations.get(name, []):
            if ins.op == "dot":
                total += self._dot_flops(ins)
            elif ins.op == "fusion" or ins.op == "call":
                for c in _CALLS.findall(ins.line):
                    total += self._fusion_flops(c)
        self._fusion_memo[name] = total
        return total

    def _instr_cost(self, ins: Instr, *, top_level: bool) -> CostResult:
        r = CostResult()
        if ins.op in _FREE_OPS:
            return r
        if ins.op == "while":
            calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                    ins.line))
            body = calls.get("body")
            cond = calls.get("condition")
            trips = self._trip_count(cond) if cond else 1
            if body:
                r.add(self.computation_cost(body).scaled(trips))
            return r
        if ins.op in ("conditional", "call", "async-start"):
            for c in _CALLS.findall(ins.line):
                r.add(self.computation_cost(c))
            r.bytes += self._io_bytes(ins)
            return r
        # collective?
        base = ins.op.replace("-start", "")
        if base in {"all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute"}:
            payload = ins.result_bytes
            if payload == 0:  # tuple-shaped: sum operand sizes
                payload = sum(self._shape_bytes(o)
                              for o in self._operand_names(ins.line, ins.op))
            r.collective_bytes += payload
            r.collective_wire_bytes += _WIRE_FACTOR.get(base, 1.0) * payload
            r.per_collective[base] = r.per_collective.get(base, 0.) + payload
            r.bytes += self._io_bytes(ins)
            return r
        if ins.op.endswith("-done"):
            return r
        # fusion: HBM unit + inner flops
        if ins.op == "fusion":
            r.bytes += self._fusion_io_bytes(ins)
            for c in _CALLS.findall(ins.line):
                r.flops += self._fusion_flops(c)
            return r
        if ins.op == "dot":
            r.flops += self._dot_flops(ins)
        r.bytes += self._io_bytes(ins)
        return r

    def _io_bytes(self, ins: Instr) -> float:
        ops = self._operand_names(ins.line, ins.op)
        if ins.op in ("dynamic-slice", "slice"):
            # a slice reads only result_bytes from the source buffer (plus
            # scalar indices) — charging the whole operand would bill a
            # 128-trip scan for reading its full input every iteration
            return float(2 * ins.result_bytes
                         + sum(min(self._shape_bytes(o), ins.result_bytes)
                               for o in ops[1:]))
        return float(sum(self._shape_bytes(o) for o in ops)
                     + ins.result_bytes)

    def _root_op(self, comp_name: str) -> Optional[Instr]:
        for ins in self.computations.get(comp_name, []):
            if "ROOT" in ins.line:
                return ins
        return None

    def _fusion_io_bytes(self, ins: Instr) -> float:
        """Fusion HBM traffic.  In-place dynamic-update-slice fusions
        alias their big input buffer: charge only the updated slice
        (read update + write slice + small operands), not the full
        buffer twice."""
        callees = _CALLS.findall(ins.line)
        dus = self._find_dus(callees[0]) if callees else None
        if dus is not None and dus.result_bytes == ins.result_bytes:
            # in-place slab write (scan-output stacking): the buffer
            # operand aliases the result; real traffic is the update
            # slab (read source + write slot) + the small operands.
            # The DUS may sit under a no-op root (convert/bitcast), so
            # this matches anywhere in the fusion, not just the root.
            dus_ops = self._operand_names(dus.line, dus.op)
            update_b = (self._shape_bytes(dus_ops[1])
                        if len(dus_ops) > 1 else 0)
            ops = self._operand_names(ins.line, ins.op)
            small = sum(b for b in (self._shape_bytes(o) for o in ops)
                        if b < ins.result_bytes)
            return float(2 * update_b + small)
        # a fusion reading big buffers but producing a small result is a
        # slice-read pattern (scan bodies consuming their per-trip slab):
        # each operand contributes at most what the fusion can consume —
        # bounded by result_bytes when the operand dwarfs it and the
        # fusion contains a dynamic-slice of it.
        ops = self._operand_names(ins.line, ins.op)
        if callees and self._fusion_has_slice(callees[0]):
            # only operands that dwarf the result (>=8x) are treated as
            # slice-reads; reduction-style full reads stay fully charged
            total = float(ins.result_bytes)
            for o in ops:
                b = self._shape_bytes(o)
                if b >= 8 * max(ins.result_bytes, 1):
                    total += ins.result_bytes
                else:
                    total += b
            return total
        return self._io_bytes(ins)

    def _find_dus(self, comp: str) -> Optional[Instr]:
        """First dynamic-update-slice inside a fusion computation."""
        if comp not in self._dus_memo:
            found = None
            for ins in self.computations.get(comp, []):
                if ins.op == "dynamic-update-slice":
                    found = ins
                    break
            self._dus_memo[comp] = found
        return self._dus_memo[comp]

    def _fusion_has_slice(self, comp: str) -> bool:
        if comp not in self._slice_memo:
            self._slice_memo[comp] = any(
                ins.op in ("dynamic-slice", "slice")
                for ins in self.computations.get(comp, []))
        return self._slice_memo[comp]

    # --------------------------------------------------------------- API
    def entry_cost(self) -> CostResult:
        entry = None
        for name in self.computations:
            if name.startswith("main") or entry is None:
                if name.startswith("main"):
                    entry = name
        if entry is None:
            entry = next(iter(self.computations))
        return self.computation_cost(entry)


    # ------------------------------------------------------ breakdown
    def breakdown(self, top: int = 25):
        """Attribute flops/bytes/collective bytes to individual
        instructions (trip-count-scaled), for dry-run 'profiling'.

        Returns (rows, loops): rows = list of dicts sorted by bytes desc;
        loops = [(body_name, trips)] for every while encountered.
        """
        rows: Dict[Tuple[str, str], Dict[str, float]] = {}
        loops: List[Tuple[str, int]] = []
        entry = self._entry_name()
        self._walk(entry, 1.0, rows, loops, set())
        out = []
        for (comp, op), v in rows.items():
            out.append({"computation": comp, "op": op, **v})
        out.sort(key=lambda r: -(r["bytes"] + r["collective_bytes"]))
        return out[:top], loops

    def _entry_name(self) -> str:
        entry = None
        for name in self.computations:
            if name.startswith("main"):
                entry = name
        return entry or next(iter(self.computations))

    def _walk(self, comp: str, scale: float, rows, loops, stack) -> None:
        if comp in stack:       # cycle guard
            return
        stack = stack | {comp}
        for ins in self.computations.get(comp, []):
            if ins.op == "while":
                calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                        ins.line))
                body, cond = calls.get("body"), calls.get("condition")
                trips = self._trip_count(cond) if cond else 1
                if body:
                    loops.append((body, trips))
                    self._walk(body, scale * trips, rows, loops, stack)
                continue
            if ins.op in ("conditional", "call", "async-start"):
                for c in _CALLS.findall(ins.line):
                    self._walk(c, scale, rows, loops, stack)
            c = self._instr_cost(ins, top_level=True)
            if c.flops or c.bytes or c.collective_bytes:
                key = (comp, self._label(ins))
                slot = rows.setdefault(key, {"flops": 0.0, "bytes": 0.0,
                                             "collective_bytes": 0.0,
                                             "count": 0.0})
                slot["flops"] += c.flops * scale
                slot["bytes"] += c.bytes * scale
                slot["collective_bytes"] += c.collective_bytes * scale
                slot["count"] += scale

    def _label(self, ins: Instr) -> str:
        """op kind + fusion-root kind + result shape, e.g.
        'fusion/dynamic-update-slice f32[2,256,512,16]'."""
        lab = ins.op
        if ins.op == "fusion":
            callees = _CALLS.findall(ins.line)
            root = self._root_op(callees[0]) if callees else None
            if root is not None:
                lab += "/" + root.op
        dims = ",".join(str(d) for d in ins.dims)
        return f"{lab} {ins.dtype}[{dims}]"


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    cost = mod.entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_wire_bytes": cost.collective_wire_bytes,
        "per_collective": cost.per_collective,
    }


def profile(hlo_text: str, top: int = 25) -> str:
    """Human-readable dry-run profile: top cost centers + loop structure."""
    mod = HloModule(hlo_text)
    rows, loops = mod.breakdown(top=top)
    lines = ["=== while loops (body x trips) ==="]
    seen = set()
    for body, trips in loops:
        if body not in seen:
            seen.add(body)
            lines.append(f"  {body:60s} x{trips}")
    lines.append(f"=== top {top} cost centers (trip-scaled, per device) ===")
    lines.append(f"{'bytes':>12s} {'coll_B':>12s} {'GFLOPs':>10s} "
                 f"{'count':>8s}  where")
    for r in rows:
        lines.append(
            f"{r['bytes']:12.3e} {r['collective_bytes']:12.3e} "
            f"{r['flops'] / 1e9:10.1f} {r['count']:8.0f}  "
            f"{r['computation'][:40]}::{r['op']}")
    return "\n".join(lines)
