"""Parameter / activation partition rules.

Rules map parameter-path regexes to logical PartitionSpecs over the
("data", "model") axes (+"pod" on the multi-pod mesh, used only by the
pod-spanning variants).  Conventions (Megatron-style 1D tensor
parallelism, TPU-adapted):

  * projections writing a model-parallel feature dim (q/k/v, gate/up,
    mamba in_proj/dt_w/conv, expert gate/up) shard their LAST axis;
  * projections contracting a model-parallel dim (o, down, expert down,
    mamba out_proj/x_proj) shard their FIRST (or middle, for stacked
    experts) axis — GSPMD inserts the reduce-scatter/all-reduce;
  * embeddings shard the vocab axis ("model") so the LM head matmul and
    softmax are vocab-parallel;
  * norms / scalar vectors / routers are replicated;
  * everything under "layers"/"enc_layers"/"dec_layers" carries a
    leading stacked-layer axis -> prepend None.

Feature dims here are all divisible by 16 for every assigned arch
(q_dim, kv_dim, d_ff, d_inner, d_expert — checked in tests).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex on keystr path, spec WITHOUT the stacked-layer axis)
_RULES = [
    # embeddings / head
    (r"\['embed'\]$", P("model", None)),
    (r"\['lm_head'\]$", P(None, "model")),
    # attention
    (r"\['attn'\]\['q'\]$", P(None, "model")),
    (r"\['attn'\]\['k'\]$", P(None, "model")),
    (r"\['attn'\]\['v'\]$", P(None, "model")),
    (r"\['attn'\]\['o'\]$", P("model", None)),
    (r"\['xattn'\]\['q'\]$", P(None, "model")),
    (r"\['xattn'\]\['k'\]$", P(None, "model")),
    (r"\['xattn'\]\['v'\]$", P(None, "model")),
    (r"\['xattn'\]\['o'\]$", P("model", None)),
    (r"\['(q|k)_norm'\]$", P(None)),
    # dense mlp (swiglu)
    (r"\['gate'\]$", P(None, "model")),
    (r"\['up'\]$", P(None, "model")),
    (r"\['down'\]$", P("model", None)),
    # whisper gelu mlp
    (r"\['mlp'\]\['up'\]$", P(None, "model")),
    (r"\['mlp'\]\['up_b'\]$", P("model")),
    (r"\['mlp'\]\['down'\]$", P("model", None)),
    (r"\['mlp'\]\['down_b'\]$", P(None)),
    # MoE: experts tensor-parallel on d_expert (uniform across E)
    (r"\['moe'\]\['router'\]$", P(None, None)),
    (r"\['moe'\]\['gate'\]$", P(None, None, "model")),
    (r"\['moe'\]\['up'\]$", P(None, None, "model")),
    (r"\['moe'\]\['down'\]$", P(None, "model", None)),
    (r"\['moe'\]\['s_gate'\]$", P(None, None, "model")),
    (r"\['moe'\]\['s_up'\]$", P(None, None, "model")),
    (r"\['moe'\]\['s_down'\]$", P(None, "model", None)),
    # mamba
    (r"\['mamba'\]\['in_proj'\]$", P(None, "model")),
    (r"\['mamba'\]\['conv_w'\]$", P(None, "model")),
    (r"\['mamba'\]\['conv_b'\]$", P("model")),
    (r"\['mamba'\]\['x_proj'\]$", P("model", None)),
    (r"\['mamba'\]\['dt_w'\]$", P(None, "model")),
    (r"\['mamba'\]\['dt_b'\]$", P("model")),
    (r"\['mamba'\]\['A_log'\]$", P("model", None)),
    (r"\['mamba'\]\['D'\]$", P("model")),
    (r"\['mamba'\]\['out_proj'\]$", P("model", None)),
    # norms
    (r"norm", P(None)),
]

_STACKED = re.compile(r"\['(layers|enc_layers|dec_layers)'\]")


def spec_for_path(path_str: str, ndim: int) -> P:
    base: Optional[P] = None
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            base = spec
            break
    if base is None:
        base = P()
    parts = list(base)
    if _STACKED.search(path_str):
        parts = [None] + parts
    # pad/trim to ndim
    parts = parts[:ndim] + [None] * (ndim - len(parts))
    return P(*parts)


def _add_fsdp(spec: P, shape, path_str: str, fsdp_size: int,
              min_size: int = 4096) -> P:
    """ZeRO-style sharding: put "data" on the largest still-replicated
    matrix dim that divides evenly.  Keeps optimizer/grad memory
    O(params/chips) instead of O(params/model_parallelism) — required to
    fit the 314B-class configs (see DESIGN.md §3)."""
    parts = list(spec)
    start = 1 if _STACKED.search(path_str) else 0
    if len(shape) - start < 2:
        return spec              # vectors: not worth gathering
    cands = [(shape[i], i) for i in range(start, len(shape))
             if parts[i] is None and shape[i] % fsdp_size == 0
             and shape[i] >= min_size]
    if not cands:
        return spec
    _, i = max(cands)
    parts[i] = "data"
    return P(*parts)


def _fix_divisibility(spec: P, shape, model_size: int) -> P:
    """Drop (or relocate) "model" from dims it doesn't divide — e.g.
    vocab 51865 (whisper) / 32001 (hymba).  Relocates to the largest
    divisible still-replicated dim when one exists."""
    parts = list(spec)
    for i, ax in enumerate(parts):
        if ax == "model" and shape[i] % model_size != 0:
            parts[i] = None
            cands = [(shape[j], j) for j in range(len(shape))
                     if parts[j] is None and shape[j] % model_size == 0
                     and shape[j] >= model_size]
            if cands:
                _, j = max(cands)
                parts[j] = "model"
    return P(*parts)


def param_specs(params, *, fsdp_size: int = 0, model_size: int = 16) -> dict:
    """Pytree of PartitionSpecs matching ``params``.  ``fsdp_size`` > 0
    additionally shards large matrices over the "data" axis (must divide
    the chosen dim)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for p, leaf in flat:
        ps = jax.tree_util.keystr(p)
        spec = spec_for_path(ps, leaf.ndim)
        spec = _fix_divisibility(spec, leaf.shape, model_size)
        if fsdp_size:
            spec = _add_fsdp(spec, leaf.shape, ps, fsdp_size)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh: Mesh, *, fsdp: bool = False):
    fsdp_size = mesh.shape.get("data", 1) if fsdp else 0
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params, fsdp_size=fsdp_size,
                    model_size=mesh.shape.get("model", 1)))


def opt_state_specs(opt_state, *, fsdp_size: int = 0, model_size: int = 16):
    """Optimizer moments mirror parameter sharding; scalars replicated."""
    def like(path, leaf):
        ps = jax.tree_util.keystr(path)
        # moments live under ['m']/['v']/['acc'] with the same sub-path
        sub = re.sub(r"^\['(m|v|acc)'\]", "", ps)
        spec = spec_for_path(sub, leaf.ndim)
        spec = _fix_divisibility(spec, leaf.shape, model_size)
        if fsdp_size:
            spec = _add_fsdp(spec, leaf.shape, sub, fsdp_size)
        return spec

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state)
    return jax.tree_util.tree_unflatten(
        treedef, [like(p, l) for p, l in flat])


def opt_state_shardings(opt_state, mesh: Mesh, *, fsdp: bool = False):
    fsdp_size = mesh.shape.get("data", 1) if fsdp else 0
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        opt_state_specs(opt_state, fsdp_size=fsdp_size,
                        model_size=mesh.shape.get("model", 1)))


def batch_specs(batch, mesh: Mesh) -> dict:
    """Shard the batch axis over ("pod","data") (whichever exist)."""
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    def spec(leaf):
        parts = [daxes] + [None] * (leaf.ndim - 1)
        return P(*parts)
    return jax.tree.map(spec, batch)


# ------------------------------------------------------------------
# activation sharding policy (§Perf Opt A)
#
# Without explicit constraints GSPMD may pick a catastrophic strategy for
# FSDP'd weights: replicate the *batch* across the data axis and
# all-reduce full (B,S,d) activations after every matmul (observed on
# falcon-mamba-7b prefill — see EXPERIMENTS.md §Perf).  The policy pins
# activations to batch-over-data so the partitioner is forced to
# all-gather the (much smaller) weights instead.
# ------------------------------------------------------------------

_ACT_POLICY: "contextvars.ContextVar" = None  # set below

import contextlib
import contextvars

_ACT_POLICY = contextvars.ContextVar("activation_policy", default=None)


@contextlib.contextmanager
def activation_policy(batch_axes, model_axis: Optional[str] = "model",
                      model_size: int = 0):
    """Enable activation constraints inside model forward fns.  Use while
    tracing/lowering under a mesh context; host-CPU runs leave it unset
    (constrain() is then a no-op).  ``model_size`` lets layers decide
    head-sharding feasibility (e.g. 8 heads on a 16-way axis)."""
    tok = _ACT_POLICY.set({"batch": tuple(batch_axes), "model": model_axis,
                           "model_size": model_size})
    try:
        yield
    finally:
        _ACT_POLICY.reset(tok)


def policy_model_size() -> int:
    pol = _ACT_POLICY.get()
    return pol["model_size"] if pol else 0


def constrain(x, *dims):
    """with_sharding_constraint(x, spec) where dims name each axis:
    "batch" -> policy batch axes, "model" -> policy model axis,
    None -> replicated.  No-op when no policy is active."""
    pol = _ACT_POLICY.get()
    if pol is None:
        return x
    parts = []
    for d in dims:
        if d == "batch":
            parts.append(pol["batch"] or None)
        elif d == "model":
            parts.append(pol["model"])
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))
