"""Building blocks shared by all architectures.

Pure functions over parameter pytrees (dicts of jnp arrays).  No module
state; everything is jit/scan/vmap friendly.  Shapes use B=batch,
S=sequence, d=d_model, H=query heads, Hk=kv heads, hd=head_dim,
E=experts, K=top_k, T=flattened tokens.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# A window value meaning "attend to everything" for global layers.
GLOBAL_WINDOW = jnp.iinfo(jnp.int32).max // 2


# --------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------
# norms / rope / activations
# --------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin of shape positions.shape + (hd/2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, hd); cos/sin: (S, hd/2) or broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    # broadcast cos/sin over the head axis: (S, 1, hd/2)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def swiglu(x, gate_w, up_w, down_w):
    g = jax.nn.silu(x @ gate_w)
    return (g * (x @ up_w)) @ down_w


def gelu_mlp(x, up_w, up_b, down_w, down_b):
    return jax.nn.gelu(x @ up_w + up_b) @ down_w + down_b


# --------------------------------------------------------------------
# attention
# --------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "q": dense_init(ks[0], (d, cfg.q_dim), dtype=dtype),
        "k": dense_init(ks[1], (d, cfg.kv_dim), dtype=dtype),
        "v": dense_init(ks[2], (d, cfg.kv_dim), dtype=dtype),
        "o": dense_init(ks[3], (cfg.q_dim, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def qkv_project(p, x, cfg: ModelConfig, positions):
    """x (B,S,d) -> q (B,S,H,hd), k,v (B,S,Hk,hd), RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["q"]).reshape(B, S, cfg.num_heads, hd)
    k = (x @ p["k"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ p["v"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def sdpa(q, k, v, *, causal: bool, window=None, q_offset=0):
    """Reference scaled-dot-product attention with GQA.

    q: (B,Sq,H,hd), k/v: (B,Sk,Hk,hd).  ``window`` limits attention to the
    last `window` keys (sliding window); None or GLOBAL_WINDOW = full.
    ``q_offset``: absolute position of q[0] relative to k[0] (for decode /
    prefill-with-prefix).
    """
    B, Sq, H, hd = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def sdpa_banded(q, k, v, *, window: int):
    """Sliding-window attention in query blocks (§Perf pair-2 it.1).

    Computes only the banded part of the score matrix: queries in blocks
    of wb = window attend to their own block and the previous one, so the
    logits tensor is (B,Hk,G,S,2w) instead of (B,Hk,G,S,S) — a S/(2w)
    reduction in attention bytes/flops for local layers (16x for
    gemma3's w=1024 @ S=32k).  Exact for any window <= wb.

    q: (B,S,H,hd), k/v: (B,S,Hk,hd); S must be a multiple of wb (callers
    pad).  Matches ``sdpa(..., causal=True, window=window)``.
    """
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    wb = window
    assert S % wb == 0, (S, wb)
    nb = S // wb
    scale = 1.0 / math.sqrt(hd)
    qb = q.reshape(B, nb, wb, Hk, G, hd)
    # keys/values for block i: blocks [i-1, i] -> (B, nb, 2wb, Hk, hd)
    kb = k.reshape(B, nb, wb, Hk, hd)
    vb = v.reshape(B, nb, wb, Hk, hd)
    zpad = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([zpad, kb[:, :-1]], axis=1), kb],
                         axis=2)                       # (B,nb,2wb,Hk,hd)
    v2 = jnp.concatenate([jnp.concatenate([zpad, vb[:, :-1]], axis=1), vb],
                         axis=2)
    logits = jnp.einsum("bnqkgh,bnskh->bnkgqs", qb, k2).astype(jnp.float32)
    logits *= scale
    # relative mask, identical for every block: q rel-pos wb+tq, k rel tk
    tq = jnp.arange(wb) + wb
    tk = jnp.arange(2 * wb)
    mask = (tk[None, :] <= tq[:, None]) & (tk[None, :] > tq[:, None] - window)
    # first block has no predecessor: mask out the zero-padded half
    first = jnp.arange(2 * wb)[None, :] >= wb
    blk_idx = jnp.arange(nb)
    mask_b = jnp.where(blk_idx[:, None, None] == 0,
                       mask[None] & first[None], mask[None])   # (nb,wb,2wb)
    logits = jnp.where(mask_b[None, :, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkgqs,bnskh->bnqkgh", probs, v2)
    return out.reshape(B, S, H, hd)


def attention(p, x, cfg: ModelConfig, *, causal=True, window=None,
              positions=None, use_kernel=False, banded=False):
    """Full-sequence attention sublayer (no cache): x (B,S,d) -> (B,S,d).

    ``banded=True`` (requires a static int ``window``) takes the blocked
    sliding-window path that never builds the S^2 score matrix."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = qkv_project(p, x, cfg, positions)
    if use_kernel:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window)
    elif banded:
        out = sdpa_banded(q, k, v, window=int(window))
    else:
        from repro.sharding import constrain, policy_model_size
        if 0 < policy_model_size() and cfg.num_heads < policy_model_size():
            # global full attention with fewer heads than the model axis:
            # GSPMD would shard the head_dim CONTRACTION and all-reduce
            # the S^2 score matrix.  Shard the QUERY sequence instead
            # (context parallelism) so scores compute locally
            # (§Perf pair-2 it.2)
            q = constrain(q, "batch", "model", None, None)
            k = constrain(k, "batch", None, None, None)
            v = constrain(v, "batch", None, None, None)
            out = sdpa(q, k, v, causal=causal, window=window)
            out = constrain(out, "batch", None, None, None)
        else:
            out = sdpa(q, k, v, causal=causal, window=window)
    return out.reshape(B, S, cfg.q_dim) @ p["o"]


def plan_window(cfg: ModelConfig, is_global, S: int):
    """(window, banded) for one layer.  Static python-bool ``is_global``
    (grouped scan) enables the structural banded path; a traced flag
    falls back to masked full attention."""
    if isinstance(is_global, bool):
        if is_global or cfg.sliding_window is None:
            return None, False
        w = cfg.sliding_window
        return w, (S % w == 0 and S // w >= 2)
    if cfg.sliding_window is None:
        return None, False
    return jnp.where(is_global, GLOBAL_WINDOW, cfg.sliding_window), False


def _rope_pos_for_decode(pos):
    """Normalize decode ``pos`` (scalar or (B,)) for rope_cos_sin so the
    resulting cos/sin broadcast against (B,1,H,hd) queries."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return pos[None]                 # (1,)   -> cos (1, hd/2)
    return pos[:, None]                  # (B,1)  -> cos (B, 1, hd/2)


def decode_attention(p, x, cfg: ModelConfig, k_cache, v_cache, pos, *,
                     cache_len_valid=None, window=None, kv_pos_of_slot=None):
    """One-token attention against a cache.

    x: (B,1,d); k_cache/v_cache: (B,C,Hk,hd) already containing this
    token's k/v (written by the caller).  ``pos``: absolute position of
    the new token — a scalar (lockstep batch) or (B,) vector
    (continuous batching: every request at its own position).
    ``kv_pos_of_slot``: (C,) or (B,C) absolute position held by each
    cache slot (ring buffers); None -> slot i holds position i.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = (x @ p["q"]).reshape(B, 1, cfg.num_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
    cos, sin = rope_cos_sin(_rope_pos_for_decode(pos), hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    C = k_cache.shape[1]
    slot_pos = kv_pos_of_slot if kv_pos_of_slot is not None else jnp.arange(C)
    slot_pos = jnp.broadcast_to(jnp.atleast_2d(slot_pos), (B, C))  # (B,C)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))[:, None]       # (B,1)
    H, Hk = cfg.num_heads, cfg.num_kv_heads
    G = H // Hk
    qg = q.reshape(B, Hk, G, hd)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32)
    logits *= 1.0 / math.sqrt(hd)
    mask = (slot_pos <= pos_b) & (slot_pos >= 0)
    if cache_len_valid is not None:
        mask &= slot_pos > pos_b - cache_len_valid
    if window is not None:
        mask &= slot_pos > pos_b - window
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache).reshape(B, 1, cfg.q_dim)
    return out @ p["o"]


def gathered_attention(q, k_cache, v_cache, qpos, kv_pos, *, window=None):
    """Multi-query attention against a gathered (paged) KV cache.

    q: (B,Sq,H,hd) already RoPE'd (``qkv_project``); k_cache/v_cache:
    (B,C,Hk,hd) gathered from the block pool and ALREADY containing the
    chunk's own k/v; qpos: (B,Sq) absolute positions of the queries;
    kv_pos: (B,C) absolute position held by each gathered slot (-1 =
    unallocated/unwritten -> masked out).

    Generalizes ``decode_attention`` to Sq queries — the chunked-prefill
    counterpart.  Masked slots hit exactly -1e30 before the softmax, so
    extra (unwritten) pool slots contribute exactly 0.0 to both the
    normalizer and the value contraction: the result is bit-identical to
    ``sdpa`` over the same live positions.
    """
    B, Sq, H, hd = q.shape
    Hk = k_cache.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32)
    logits *= scale
    kv = kv_pos[:, None, :]                              # (B,1,C)
    qp = qpos[:, :, None]                                # (B,Sq,1)
    mask = (kv <= qp) & (kv >= 0)                        # (B,Sq,C)
    if window is not None:
        mask &= kv > qp - window
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache)
    return out.reshape(B, Sq, H, hd)


def project_kv_one(p, x, cfg: ModelConfig, pos):
    """k/v for a single new token: x (B,1,d) -> (B,1,Hk,hd) each.
    ``pos`` scalar or (B,)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    k = (x @ p["k"]).reshape(B, 1, cfg.num_kv_heads, hd)
    v = (x @ p["v"]).reshape(B, 1, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    cos, sin = rope_cos_sin(_rope_pos_for_decode(pos), hd, cfg.rope_theta)
    k = apply_rope(k, cos, sin)
    return k, v


# --------------------------------------------------------------------
# MoE (capacity-based sort dispatch — no (T,E,C) one-hot tensor)
# --------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype):
    mc = cfg.moe
    d = cfg.d_model
    de = mc.d_expert or cfg.d_ff
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, mc.num_experts), dtype=jnp.float32),
        "gate": dense_init(ks[1], (mc.num_experts, d, de), dtype=dtype),
        "up": dense_init(ks[2], (mc.num_experts, d, de), dtype=dtype),
        "down": dense_init(ks[3], (mc.num_experts, de, d), dtype=dtype),
    }
    if mc.num_shared:
        p["s_gate"] = dense_init(ks[4], (mc.num_shared, d, de), dtype=dtype)
        p["s_up"] = dense_init(ks[5], (mc.num_shared, d, de), dtype=dtype)
        p["s_down"] = dense_init(ks[6], (mc.num_shared, de, d), dtype=dtype)
    return p


def moe_block(p, x, cfg: ModelConfig, *, capacity_factor: float = 1.25):
    """MoE with shard-local grouped dispatch.

    x: (T, d) flattened tokens, or (G, Tg, d) grouped tokens (G = batch
    rows).  The grouped form routes every group INDEPENDENTLY (per-group
    capacity), which keeps the dispatch/combine gathers local to the
    data shard that owns the group — a global argsort dispatch forces
    GSPMD to all-gather every shard's dispatch buffer before the expert
    matmuls (16x redundant expert compute, §Perf pair-3 it.2).  This is
    the standard per-device-capacity design (Switch Transformer).
    Returns (y like x, aux_loss scalar).
    """
    if x.ndim == 3:
        y, aux = jax.vmap(
            lambda xg: _moe_block_flat(p, xg, cfg,
                                       capacity_factor=capacity_factor))(x)
        return y, jnp.mean(aux)
    return _moe_block_flat(p, x, cfg, capacity_factor=capacity_factor)


def _moe_block_flat(p, x, cfg: ModelConfig, *, capacity_factor: float = 1.25):
    """x: (T, d) flattened tokens -> (y (T, d), aux_loss scalar).

    Sort-based capacity dispatch: assignments are sorted by expert id,
    ranked within expert, scattered into an (E*C, d) buffer, processed
    with one batched einsum per FFN matrix, and combined back.  FLOPs =
    E*C*d*de ~= T*K*cf*d*de (near-optimal; no E/K dense blowup).
    """
    mc = cfg.moe
    T, d = x.shape
    E, K = mc.num_experts, mc.top_k
    C = max(K, int(math.ceil(T * K / E * capacity_factor)))

    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)                     # (T,E)
    topw, topi = jax.lax.top_k(probs, K)                        # (T,K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    flat_e = topi.reshape(-1)                                    # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    idx = jnp.arange(T * K)
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = idx - seg_start                                       # rank in expert
    valid_sorted = rank < C
    dump = E * C                                                 # overflow slot
    dest_sorted = jnp.where(valid_sorted, sorted_e * C + rank, dump)

    tok_of_assign = idx // K                                     # (T*K,)
    slot_token = jnp.zeros((E * C + 1,), jnp.int32).at[dest_sorted].set(
        tok_of_assign[order].astype(jnp.int32), mode="drop")
    slot_used = jnp.zeros((E * C + 1,), x.dtype).at[dest_sorted].set(
        valid_sorted.astype(x.dtype), mode="drop")

    xin = x[slot_token[:-1]] * slot_used[:-1, None]              # (E*C, d)
    xe = xin.reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"]).reshape(E * C, d)

    # combine: map each assignment back to its slot
    slot_of_assign = jnp.zeros((T * K,), jnp.int32).at[order].set(
        jnp.minimum(dest_sorted, E * C - 1).astype(jnp.int32))
    kept = jnp.zeros((T * K,), x.dtype).at[order].set(valid_sorted.astype(x.dtype))
    y_assign = ye[slot_of_assign] * (topw.reshape(-1, 1).astype(x.dtype) * kept[:, None])
    y = y_assign.reshape(T, K, d).sum(axis=1)

    if mc.num_shared:
        hs = jax.nn.silu(jnp.einsum("td,sdf->tsf", x, p["s_gate"]))
        hs = hs * jnp.einsum("td,sdf->tsf", x, p["s_up"])
        y = y + jnp.einsum("tsf,sfd->td", hs, p["s_down"])

    # load-balance aux loss (Switch-style)
    frac_tokens = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
    mean_prob = jnp.mean(probs, axis=0)
    aux = mc.load_balance_coef * E * jnp.sum(frac_tokens * mean_prob)
    return y, aux


# --------------------------------------------------------------------
# Mamba-1 selective SSM
# --------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype):
    ssm = cfg.ssm
    d, di, n, dtr = cfg.d_model, cfg.d_inner, ssm.state_dim, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real A init: A[:, j] = -(j+1)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[5], (di,), jnp.float32,
                                   math.log(1e-3), math.log(1e-1)))))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (ssm.conv_dim, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), dtype=dtype),
        "dt_w": dense_init(ks[3], (dtr, di), dtype=dtype),
        "dt_b": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def causal_conv1d(x, w, b, prev=None):
    """Depthwise causal conv: x (B,S,di), w (cw,di) -> (B,S,di).

    ``prev``: (B,cw-1,di) raw inputs preceding x (carried conv state for
    chunked prefill); None = zeros (sequence start — unchanged math)."""
    cw = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(cw):  # cw is tiny (4): unrolled taps, no conv primitive
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def ssm_scan_seq(u, dt, A, Bmat, Cmat, sub: int = 16, h0=None):
    """Selective scan via sub-block sequential recurrence (§Perf pair-1
    iteration 2).

    Mirrors the Pallas ``mamba_scan`` kernel's dataflow in pure JAX: a
    ``lax.scan`` over S/sub sub-blocks whose body unrolls ``sub`` steps,
    computing the decay exp(dt·A), the input injection dt·u·B and the
    output y = C·h ON THE FLY — h lives in registers between unrolled
    steps, so HBM sees only the (B,sub,di) u/dt slabs, the (B,sub,n)
    B/C slabs and the (B,sub,di) y slab, never a (B,S,di,n) tensor.
    ~8x less HBM traffic than the associative-scan form at the price of
    S/sub sequential HLO steps — the right trade for forward-only
    passes (prefill); training keeps the associative form (shorter
    dependence chain for the backward pass).

    Shapes as in ``ssm_scan_chunked``; exact (f32 recurrence).
    """
    Bsz, S, di = u.shape
    n = A.shape[1]
    pad = (-S) % sub
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nblk = Sp // sub
    rs = lambda t: t.reshape(Bsz, nblk, sub, -1).swapaxes(0, 1)
    ub, dtb, Bb, Cb = rs(u), rs(dt), rs(Bmat), rs(Cmat)
    negA = -jnp.exp(A)                                   # (di,n) f32

    def blk(h, args):
        ublk, dtblk, bblk, cblk = args                   # (B,sub,·)
        dtf = dtblk.astype(jnp.float32)
        duf = dtf * ublk.astype(jnp.float32)             # (B,sub,di)
        ys = []
        for t in range(sub):                             # unrolled; h in regs
            a_t = jnp.exp(dtf[:, t, :, None] * negA[None])        # (B,di,n)
            x_t = duf[:, t, :, None] * bblk[:, t, None, :].astype(jnp.float32)
            h = a_t * h + x_t
            ys.append(jnp.einsum(
                "bdn,bn->bd", h, cblk[:, t].astype(jnp.float32)))
        # keep the stacked output f32: a bf16 stack makes XLA round-trip
        # the whole (nblk,B,sub,di) buffer through f32 converts on every
        # trip (observed on the CPU pipeline) instead of an in-place
        # dynamic-update-slice; one cast after the scan is free
        return h, jnp.stack(ys, axis=1)                      # (B,sub,di) f32

    if h0 is None:
        h0 = jnp.zeros((Bsz, di, n), jnp.float32)
    h_last, yb = jax.lax.scan(blk, h0.astype(jnp.float32), (ub, dtb, Bb, Cb))
    y = yb.swapaxes(0, 1).reshape(Bsz, Sp, di)[:, :S].astype(u.dtype)
    return y, h_last.astype(u.dtype)


def ssm_scan_chunked(u, dt, A, Bmat, Cmat, chunk: int = 256):
    """Selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t;  y = C_t.h.

    u, dt: (B,S,di); Bmat, Cmat: (B,S,n); A: (di,n).  Sequential lax.scan
    over S/chunk chunks (bounded transients), associative scan inside each
    chunk.  Returns y (B,S,di) and final state (B,di,n).
    """
    Bsz, S, di = u.shape
    n = A.shape[1]
    pad = (-S) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nchunk = Sp // chunk
    # reshape to (nchunk, B, chunk, ...)
    rs = lambda t: t.reshape(Bsz, nchunk, chunk, -1).swapaxes(0, 1)
    uc, dtc, Bc, Cc = rs(u), rs(dt), rs(Bmat), rs(Cmat)

    def chunk_step(h0, args):
        uch, dtch, bch, cch = args                     # (B,chunk,·)
        dtf = dtch.astype(jnp.float32)
        a = jnp.exp(dtf[..., None] * (-jnp.exp(A))[None, None])    # (B,c,di,n)
        x_in = ((dtf * uch.astype(jnp.float32))[..., None]
                * bch.astype(jnp.float32)[:, :, None, :])          # (B,c,di,n)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_sc, x_sc = jax.lax.associative_scan(combine, (a, x_in), axis=1)
        h = a_sc * h0[:, None] + x_sc                               # (B,c,di,n)
        y = jnp.einsum("bcdn,bcn->bcd", h, cch.astype(jnp.float32))
        return h[:, -1], y.astype(uch.dtype)

    h0 = jnp.zeros((Bsz, di, n), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (uc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(Bsz, Sp, di)[:, :S]
    return y, h_last.astype(u.dtype)


def mamba_forward(p, x, cfg: ModelConfig, *, use_kernel=False,
                  return_state=False, scan_impl: str = "assoc"):
    """Full-sequence Mamba block: x (B,S,d) -> (B,S,d).

    ``return_state=True`` additionally returns the decode cache
    {"conv": (B,cw-1,di) raw conv inputs, "ssm": (B,di,n) final state}
    from the SAME scan — prefill must not run the scan twice (§Perf
    Opt B: the duplicated scan doubled falcon-mamba's memory term)."""
    ssm = cfg.ssm
    n, dtr = ssm.state_dim, cfg.dt_rank
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(causal_conv1d(x_in, p["conv_w"], p["conv_b"]))
    dbc = x_c @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_w"]).astype(jnp.float32)
                         + p["dt_b"][None, None]).astype(x.dtype)
    if use_kernel:
        from repro.kernels.mamba_scan.ops import mamba_scan
        y, h_last = mamba_scan(x_c, dt, p["A_log"], Bm, Cm)
    elif scan_impl == "seq":
        y, h_last = ssm_scan_seq(x_c, dt, p["A_log"], Bm, Cm)
    else:
        y, h_last = ssm_scan_chunked(x_c, dt, p["A_log"], Bm, Cm)
    y = y + x_c * p["D"][None, None].astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    if return_state:
        cw = ssm.conv_dim
        return out, {"conv": x_in[:, -(cw - 1):, :], "ssm": h_last}
    return out


def mamba_decode(p, x, cfg: ModelConfig, conv_state, ssm_state):
    """One-token Mamba step.

    x: (B,1,d); conv_state: (B,cw-1,di) previous inputs; ssm_state:
    (B,di,n).  Returns (y (B,1,d), new_conv_state, new_ssm_state).
    """
    ssm = cfg.ssm
    n, dtr = ssm.state_dim, cfg.dt_rank
    xz = x[:, 0] @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                      # (B,di)
    window = jnp.concatenate([conv_state, x_in[:, None]], axis=1)  # (B,cw,di)
    x_c = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"][None]
    x_c = jax.nn.silu(x_c)
    dbc = x_c @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_w"]).astype(jnp.float32)
                         + p["dt_b"][None]).astype(x.dtype)
    a = jnp.exp(dt[..., None] * (-jnp.exp(p["A_log"]))[None])     # (B,di,n)
    h = (a * ssm_state.astype(jnp.float32)
         + ((dt * x_c)[..., None] * Bm[:, None, :]).astype(jnp.float32))
    y = jnp.einsum("bdn,bn->bd", h.astype(x.dtype), Cm)
    y = y + x_c * p["D"][None].astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out[:, None], window[:, 1:], h.astype(ssm_state.dtype)


def mamba_forward_chunk(p, x, cfg: ModelConfig, conv_state, ssm_state):
    """``mamba_forward`` with carried decode state — the chunked-prefill
    SSM path.

    x: (B,S,d) chunk; conv_state: (B,cw-1,di) raw conv inputs preceding
    the chunk; ssm_state: (B,di,n).  Returns (out (B,S,d), state dict as
    in ``mamba_forward(return_state=True)``).  Runs the same f32
    recurrence as ``mamba_forward(..., scan_impl="seq")`` continued from
    the given state, so a prompt processed in chunks matches one-shot
    prefill bit-for-bit (f32 models; bf16 pays one state-dtype
    round-trip per chunk boundary).
    """
    ssm = cfg.ssm
    n, dtr = ssm.state_dim, cfg.dt_rank
    cw = ssm.conv_dim
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(causal_conv1d(x_in, p["conv_w"], p["conv_b"],
                                    prev=conv_state))
    dbc = x_c @ p["x_proj"]
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_w"]).astype(jnp.float32)
                         + p["dt_b"][None, None]).astype(x.dtype)
    y, h_last = ssm_scan_seq(x_c, dt, p["A_log"], Bm, Cm, h0=ssm_state)
    y = y + x_c * p["D"][None, None].astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    new_conv = jnp.concatenate([conv_state.astype(x_in.dtype), x_in],
                               axis=1)[:, -(cw - 1):, :]
    return out, {"conv": new_conv, "ssm": h_last}
