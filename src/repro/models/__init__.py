"""Unified model API dispatching on arch family.

All entry points are pure functions:
  init_params(cfg, key)                         -> params pytree
  loss_fn(params, batch, cfg, **kw)             -> (loss, metrics)
  init_cache(cfg, params, batch_size, cache_len, frames=None) -> cache
  decode_step(params, cache, token, pos, cfg)   -> (logits, cache)
  prefill(params, tokens, cfg, cache_len, **kw) -> (logits, cache)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


def init_params(cfg: ModelConfig, key):
    if cfg.is_encoder_decoder:
        return encdec.init_params(cfg, key)
    return lm.init_params(cfg, key)


def loss_fn(params, batch, cfg: ModelConfig, *, use_kernels=False, remat=True,
            logit_chunk=None):
    if cfg.is_encoder_decoder:
        return encdec.loss_fn(params, batch, cfg, use_kernels=use_kernels,
                              remat=remat)
    return lm.loss_fn(params, batch, cfg, use_kernels=use_kernels,
                      remat=remat, logit_chunk=logit_chunk)


def init_cache(cfg: ModelConfig, params, batch_size: int, cache_len: int,
               frames=None):
    if cfg.is_encoder_decoder:
        assert frames is not None, "enc-dec cache needs encoder frames"
        return encdec.init_cache(cfg, params, frames, cache_len)
    return lm.init_cache(cfg, batch_size, cache_len)


def decode_step(params, cache, token, pos, cfg: ModelConfig, *, active=None):
    if cfg.is_encoder_decoder:
        assert active is None, "lane masking is decoder-only-LM serving"
        return encdec.decode_step(params, cache, token, pos, cfg)
    return lm.decode_step(params, cache, token, pos, cfg, active=active)


def prefill(params, tokens, cfg: ModelConfig, cache_len: int, *,
            prefix_emb=None, use_kernels=False, last_only=False):
    assert not cfg.is_encoder_decoder
    return lm.prefill(params, tokens, cfg, cache_len, prefix_emb=prefix_emb,
                      use_kernels=use_kernels, last_only=last_only)


def init_paged_cache(cfg: ModelConfig, n_lanes: int, num_blocks: int,
                     block_size: int):
    """Block-pool KV cache for paged serving (decoder-only LMs)."""
    assert not cfg.is_encoder_decoder
    return lm.init_paged_cache(cfg, n_lanes, num_blocks, block_size)


def decode_step_paged(params, cache, token, pos, cfg: ModelConfig,
                      tables, active, *, block_size: int):
    assert not cfg.is_encoder_decoder
    return lm.decode_step_paged(params, cache, token, pos, cfg,
                                tables, active, block_size=block_size)


def prefill_chunk_paged(params, cache, tokens, pos0, cfg: ModelConfig,
                        table_row, lane: int, *, block_size: int):
    assert not cfg.is_encoder_decoder
    return lm.prefill_chunk_paged(params, cache, tokens, pos0, cfg,
                                  table_row, lane, block_size=block_size)


def example_batch(cfg: ModelConfig, batch: int, seq: int, key=None):
    """Small concrete batch for smoke tests (deterministic)."""
    import numpy as np
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int64)
    out = {"tokens": jnp.asarray(tokens, jnp.int32)}
    if cfg.is_encoder_decoder:
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    elif cfg.frontend is not None:
        out["prefix_emb"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out


__all__ = ["init_params", "loss_fn", "init_cache", "decode_step", "prefill",
           "init_paged_cache", "decode_step_paged", "prefill_chunk_paged",
           "example_batch", "lm", "encdec"]
