"""Decoder-only language model covering dense / moe / ssm / hybrid / vlm
architectures.  One parameter pytree, layers stacked on a leading axis and
driven by ``jax.lax.scan`` so HLO size (and CPU compile time) is O(1) in
depth.

Cache layout (decode):
  k, v        : (L, B, C, Hk, hd)      C = cache length (ring buffer)
  conv, ssm   : (L, B, cw-1, di), (L, B, di, n)   for ssm/hybrid archs
Ring-buffer semantics: position p lives in slot p % C; the absolute
position held by slot i at decode position `pos` is pos - ((pos - i) % C).
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import constrain


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _has_attn(cfg: ModelConfig) -> bool:
    return cfg.arch_type != "ssm"


def _has_mamba(cfg: ModelConfig) -> bool:
    return cfg.arch_type == "ssm" or cfg.hybrid


def _has_mlp(cfg: ModelConfig) -> bool:
    return cfg.arch_type != "ssm"


# --------------------------------------------------------------------
# init
# --------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {}
    if cfg.arch_type == "ssm":
        p["norm"] = jnp.zeros((cfg.d_model,), dt)
        p["mamba"] = L.init_mamba(ks[0], cfg, dt)
        return p
    p["attn_norm"] = jnp.zeros((cfg.d_model,), dt)
    p["attn"] = L.init_attention(ks[0], cfg, dt)
    if cfg.hybrid:
        p["mamba"] = L.init_mamba(ks[1], cfg, dt)
    p["mlp_norm"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[2], cfg, dt)
    else:
        kg, ku, kd = jax.random.split(ks[2], 3)
        p["gate"] = L.dense_init(kg, (cfg.d_model, cfg.d_ff), dtype=dt)
        p["up"] = L.dense_init(ku, (cfg.d_model, cfg.d_ff), dtype=dt)
        p["down"] = L.dense_init(kd, (cfg.d_ff, cfg.d_model), dtype=dt)
    return p


def init_params(cfg: ModelConfig, key):
    dt = _dtype(cfg)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": L.dense_init(k_emb, (cfg.vocab_size, cfg.d_model),
                              scale=0.02, dtype=dt),
        "layers": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype=dt)
    return p


def layer_is_global(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) bool: which layers use full (global) attention."""
    idx = jnp.arange(cfg.num_layers)
    if cfg.sliding_window is None:
        return jnp.ones((cfg.num_layers,), bool)
    if cfg.global_every is None:
        return jnp.zeros((cfg.num_layers,), bool)
    return (idx + 1) % cfg.global_every == 0


def _grouped(cfg: ModelConfig):
    """Grouped-scan geometry for local/global interleaved archs (gemma3):
    (n_groups, group_size, n_tail) or None for uniform archs."""
    if cfg.global_every is None or cfg.sliding_window is None:
        return None
    g = cfg.global_every
    ng = cfg.num_layers // g
    if ng == 0:
        return None
    return ng, g, cfg.num_layers - ng * g


def _run_layers(layers_tree, carry, body, cfg: ModelConfig, *,
                remat: bool = False):
    """Drive ``body(p_layer, carry, is_global) -> (carry, out)`` over all
    layers.

    Uniform archs: one lax.scan with a traced is_global flag (O(1) HLO).
    Local/global interleaved archs (gemma3 5:1): a scan over GROUPS whose
    body unrolls the g layers with STATIC globality, so local layers can
    use banded sliding-window attention structurally — a traced
    ``jnp.where(window)`` flag cannot remove the S^2 score tensor
    (§Perf pair-2 it.1).  Remainder layers run unrolled.
    Returns (carry, outs stacked on a leading (L, ...) axis or None).
    """
    grp = _grouped(cfg)
    if grp is None:
        is_global = layer_is_global(cfg)

        def sbody(c, scanned):
            p, gflag = scanned
            return body(p, c, gflag)

        if remat:
            sbody = jax.checkpoint(sbody)
        return jax.lax.scan(sbody, carry, (layers_tree, is_global))

    ng, g, n_tail = grp
    head = jax.tree.map(lambda l: l[:ng * g].reshape((ng, g) + l.shape[1:]),
                        layers_tree)

    def gbody(c, pgrp):
        outs = []
        for j in range(g):                       # unrolled: static bools
            pj = jax.tree.map(lambda l: l[j], pgrp)
            c, o = body(pj, c, (j + 1) % g == 0)
            outs.append(o)
        if outs[0] is None:
            return c, None
        return c, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    if remat:
        gbody = jax.checkpoint(gbody)
    carry, outs_head = jax.lax.scan(gbody, carry, head)
    outs = None
    if outs_head is not None:
        outs = jax.tree.map(lambda l: l.reshape((ng * g,) + l.shape[2:]),
                            outs_head)
    tail_outs = []
    for i in range(ng * g, cfg.num_layers):
        pj = jax.tree.map(lambda l: l[i], layers_tree)
        step = jax.checkpoint(body) if remat else body
        carry, o = step(pj, carry, (i + 1) % g == 0)
        tail_outs.append(o)
    if tail_outs and tail_outs[0] is not None:
        tail_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *tail_outs)
        outs = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                            outs, tail_stacked)
    return carry, outs


# --------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------

def _layer_apply(p, x, cfg: ModelConfig, is_global, positions, use_kernels):
    """One layer, full sequence.  Returns (x, aux_loss).  ``is_global``
    may be a static python bool (grouped scan -> banded local attention)
    or a traced flag (uniform scan -> masked full attention)."""
    aux = jnp.float32(0.0)
    if cfg.arch_type == "ssm":
        h = L.rms_norm(x, p["norm"], cfg.rms_eps)
        return x + L.mamba_forward(p["mamba"], h, cfg, use_kernel=use_kernels), aux
    window, banded = L.plan_window(cfg, is_global, x.shape[1])
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    a = L.attention(p["attn"], h, cfg, causal=True, window=window,
                    positions=positions, use_kernel=use_kernels,
                    banded=banded)
    if cfg.hybrid:
        m = L.mamba_forward(p["mamba"], h, cfg, use_kernel=use_kernels)
        a = 0.5 * (a + m)          # Hymba-style parallel-head mean fusion
    x = x + a
    h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    if cfg.moe is not None:
        # dispatch mode per arch (MoEConfig.dispatch, §Perf pair-3 it.2
        # + §Perf deepseek iteration): grouped wins for fine-grained
        # many-expert MoE, flat for few-big-expert MoE
        if cfg.moe.dispatch == "grouped":
            y, aux = L.moe_block(p["moe"], h2, cfg)
        else:
            B, S, d = h2.shape
            y, aux = L.moe_block(p["moe"], h2.reshape(B * S, d), cfg)
            y = y.reshape(B, S, d)
    else:
        y = L.swiglu(h2, p["gate"], p["up"], p["down"])
    return x + y, aux


def forward(params, tokens, cfg: ModelConfig, *, prefix_emb=None,
            use_kernels: bool = False, remat: bool = True):
    """tokens (B,S) -> logits (B, P+S, V).  prefix_emb: (B,P,d) stub
    embeddings (vlm patch / audio frame) prepended to the token stream."""
    x = params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", None, None)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)

    def body(p, carry, gflag):
        x, aux_sum = carry
        x = constrain(x, "batch", None, None)
        x, aux = _layer_apply(p, x, cfg, gflag, positions, use_kernels)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = _run_layers(params["layers"], (x, jnp.float32(0.0)),
                                  body, cfg, remat=remat)
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return logits, aux_sum


def backbone(params, tokens, cfg: ModelConfig, *, prefix_emb=None,
             use_kernels: bool = False, remat: bool = True):
    """Like ``forward`` but stops before the LM head: returns the final
    hidden states (B, P+S, d) and the accumulated MoE aux loss."""
    x = params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])

    def body(p, carry, gflag):
        x, aux_sum = carry
        x = constrain(x, "batch", None, None)
        x, aux = _layer_apply(p, x, cfg, gflag, positions, use_kernels)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = _run_layers(params["layers"], (x, jnp.float32(0.0)),
                                  body, cfg, remat=remat)
    return L.rms_norm(x, params["final_norm"], cfg.rms_eps), aux_sum


def chunked_ce(x, head, tokens, P: int, chunk: int):
    """Sequence-chunked cross-entropy: never materializes the full
    (B, S, V) logits — each lax.scan step computes a (B, chunk, V) slab.
    ``head``: (d, V) projection.  Predicts tokens[:, 1:] from hidden
    states at positions P .. P+S-2."""
    B, S = tokens.shape
    hs = x[:, P:P + S - 1]                       # (B, S-1, d) predictors
    tgt = tokens[:, 1:]                          # (B, S-1)
    n = S - 1
    pad = (-n) % chunk
    if pad:
        hs = jnp.pad(hs, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
    nchunk = (n + pad) // chunk
    hs = hs.reshape(B, nchunk, chunk, -1).swapaxes(0, 1)
    tgt = tgt.reshape(B, nchunk, chunk).swapaxes(0, 1)
    cmask = (jnp.arange(nchunk * chunk).reshape(nchunk, chunk)[:, None, :]
             < n).astype(jnp.float32)            # (nchunk, 1, chunk)

    def step(tot, args):
        h, t, m = args
        logits = (h @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return tot + jnp.sum((logz - gold) * m), None

    tot, _ = jax.lax.scan(step, jnp.float32(0.0), (hs, tgt, cmask))
    return tot / (B * n)


def loss_fn(params, batch, cfg: ModelConfig, *, use_kernels: bool = False,
            remat: bool = True, logit_chunk: Optional[int] = None):
    """Next-token cross-entropy.  batch: {"tokens": (B,S)} (+"prefix_emb").

    Returns (loss, metrics).  Loss is mean over predicted positions; MoE
    aux load-balance loss is added (per-layer mean).  ``logit_chunk``:
    compute the CE in sequence chunks of this size (memory-bounded LM
    head for large-vocab archs — the (B,S,V) logits never materialize).
    """
    tokens = batch["tokens"]
    prefix = batch.get("prefix_emb")
    P = 0 if prefix is None else prefix.shape[1]
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    if logit_chunk is not None:
        x, aux = backbone(params, tokens, cfg, prefix_emb=prefix,
                          use_kernels=use_kernels, remat=remat)
        ce = chunked_ce(x, head, tokens, P, logit_chunk)
    else:
        logits, aux = forward(params, tokens, cfg, prefix_emb=prefix,
                              use_kernels=use_kernels, remat=remat)
        pred = logits[:, P:-1].astype(jnp.float32)       # predicts tokens[1:]
        tgt = tokens[:, 1:]
        logz = jax.nn.logsumexp(pred, axis=-1)
        gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - gold)
    total = ce + aux / max(cfg.num_layers, 1)
    return total, {"ce": ce, "aux": aux / max(cfg.num_layers, 1)}


# --------------------------------------------------------------------
# KV / state cache + decode
# --------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dt = _dtype(cfg)
    Ln = cfg.num_layers
    cache = {}
    if _has_attn(cfg):
        hd = cfg.resolved_head_dim
        cache["k"] = jnp.zeros((Ln, batch, cache_len, cfg.num_kv_heads, hd), dt)
        cache["v"] = jnp.zeros((Ln, batch, cache_len, cfg.num_kv_heads, hd), dt)
    if _has_mamba(cfg):
        ssm = cfg.ssm
        di = cfg.d_inner
        cache["conv"] = jnp.zeros((Ln, batch, ssm.conv_dim - 1, di), dt)
        cache["ssm"] = jnp.zeros((Ln, batch, di, ssm.state_dim), dt)
    return cache


def _mask_state(new, old, active):
    """Keep ``old`` state rows for inactive lanes (retired slots must not
    accumulate garbage).  active: (B,) bool; state leading axis is B."""
    if active is None:
        return new
    keep = active.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(keep, new, old)


def _decode_layer(p, x, cfg: ModelConfig, is_global, cache_slice, pos, C,
                  active=None):
    """One layer, one token.  cache_slice: this layer's cache entries
    (already containing slots for positions < pos).  Returns (x, new_slice).

    ``active``: optional (B,) bool — continuous batching's lane mask.
    Inactive lanes (freed slots still riding the fixed-shape batch) keep
    their cache/state rows untouched instead of writing garbage at
    whatever stale position they hold."""
    new_cache = {}
    if cfg.arch_type == "ssm":
        h = L.rms_norm(x, p["norm"], cfg.rms_eps)
        y, conv, ssm = L.mamba_decode(p["mamba"], h, cfg,
                                      cache_slice["conv"], cache_slice["ssm"])
        return x + y, {"conv": _mask_state(conv, cache_slice["conv"], active),
                       "ssm": _mask_state(ssm, cache_slice["ssm"], active)}
    h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
    k_new, v_new = L.project_kv_one(p["attn"], h, cfg, pos)
    slot = jnp.mod(jnp.asarray(pos), C)
    if slot.ndim == 0:                   # lockstep batch: one slot
        if active is not None:
            old_k = jax.lax.dynamic_slice_in_dim(cache_slice["k"], slot, 1, 1)
            old_v = jax.lax.dynamic_slice_in_dim(cache_slice["v"], slot, 1, 1)
            k_new = _mask_state(k_new, old_k, active)
            v_new = _mask_state(v_new, old_v, active)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_slice["k"], k_new, slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache_slice["v"], v_new, slot, axis=1)
    else:                                # per-request positions (B,)
        B = k_new.shape[0]
        rows = jnp.arange(B)
        k_w, v_w = k_new[:, 0], v_new[:, 0]
        if active is not None:
            k_w = _mask_state(k_w, cache_slice["k"][rows, slot], active)
            v_w = _mask_state(v_w, cache_slice["v"][rows, slot], active)
        k_cache = cache_slice["k"].at[rows, slot].set(k_w)
        v_cache = cache_slice["v"].at[rows, slot].set(v_w)
    new_cache["k"], new_cache["v"] = k_cache, v_cache
    window = None
    if cfg.sliding_window is not None:
        window = jnp.where(is_global, L.GLOBAL_WINDOW, cfg.sliding_window)
    pos_c = jnp.asarray(pos)[..., None]                  # (1,) or (B,1)
    kv_pos = pos_c - jnp.mod(pos_c - jnp.arange(C), C)   # (C,) or (B,C)
    a = L.decode_attention(p["attn"], h, cfg, k_cache, v_cache, pos,
                           window=window, kv_pos_of_slot=kv_pos)
    if cfg.hybrid:
        m, conv, ssm = L.mamba_decode(p["mamba"], h, cfg,
                                      cache_slice["conv"], cache_slice["ssm"])
        a = 0.5 * (a + m)
        new_cache["conv"] = _mask_state(conv, cache_slice["conv"], active)
        new_cache["ssm"] = _mask_state(ssm, cache_slice["ssm"], active)
    x = x + a
    h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
    if cfg.moe is not None:
        B = h2.shape[0]
        y, _ = L.moe_block(p["moe"], h2.reshape(B, -1), cfg)
        y = y.reshape(B, 1, -1)
    else:
        y = L.swiglu(h2, p["gate"], p["up"], p["down"])
    return x + y, new_cache


def decode_step(params, cache, token, pos, cfg: ModelConfig, *, active=None):
    """token (B,) int32, pos scalar or (B,) int32 -> (logits (B,V), new
    cache).  ``active``: optional (B,) bool lane mask — inactive lanes
    compute but never write to cache/state (continuous batching)."""
    x = params["embed"][token][:, None, :] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    is_global = layer_is_global(cfg)
    C = (cache["k"].shape[2] if "k" in cache else 0)

    def body(x, scanned):
        p, g, cache_slice = scanned
        x, new_slice = _decode_layer(p, x, cfg, g, cache_slice, pos, C,
                                     active=active)
        return x, new_slice

    x, new_cache = jax.lax.scan(body, x, (params["layers"], is_global, cache))
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x[:, 0] @ params["embed"].T
    else:
        logits = x[:, 0] @ params["lm_head"]
    return logits, new_cache


def _ring_scatter(kv, S_total: int, C: int):
    """Place the last min(C, S_total) positions of kv (B,S,Hk,hd) into a
    (B,C,Hk,hd) ring buffer at slot p % C (position p's canonical slot)."""
    take = min(C, S_total)
    positions = jnp.arange(S_total - take, S_total)
    slots = jnp.mod(positions, C)
    buf = jnp.zeros((kv.shape[0], C) + kv.shape[2:], kv.dtype)
    return buf.at[:, slots].set(kv[:, -take:])


def prefill(params, tokens, cfg: ModelConfig, cache_len: int, *,
            prefix_emb=None, use_kernels: bool = False,
            last_only: bool = False):
    """Forward pass that also fills the KV cache (first cache_len
    positions).  Returns (logits (B, S_total, V), cache).

    ``last_only=True`` computes logits for the final position only
    (shape (B, 1, V)) — serving and the dry-run need just the next-token
    distribution, and XLA does NOT dead-code the (B,S,V) head matmul +
    vocab-parallel all-reduce through a later slice (§Perf Opt C)."""
    B, S = tokens.shape
    x = params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", None, None)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)

    def body(p, carry, g):
        x, = carry
        x = constrain(x, "batch", None, None)
        new_slice = {}
        if cfg.arch_type == "ssm":
            h = L.rms_norm(x, p["norm"], cfg.rms_eps)
            # one scan yields y AND the decode state (§Perf Opt B);
            # forward-only -> sequential sub-block scan (§Perf pair-1 it.2)
            y, state = L.mamba_forward(p["mamba"], h, cfg,
                                       use_kernel=use_kernels,
                                       return_state=True,
                                       scan_impl=os.environ.get(
                                           "REPRO_SSM_SCAN", "seq"))
            new_slice.update(state)
            x = x + y
            return (x,), new_slice
        window, banded = L.plan_window(cfg, g, S_total)
        h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
        q, k, v = L.qkv_project(p["attn"], h, cfg, positions)
        if use_kernels:
            from repro.kernels.flash_attention.ops import flash_attention
            a = flash_attention(q, k, v, causal=True, window=window)
        elif banded:
            a = L.sdpa_banded(q, k, v, window=int(window))
        else:
            from repro.sharding import policy_model_size
            if 0 < policy_model_size() \
                    and cfg.num_heads < policy_model_size():
                # see layers.attention: query-sequence sharding for
                # few-head global attention (§Perf pair-2 it.2)
                q = constrain(q, "batch", "model", None, None)
                k = constrain(k, "batch", None, None, None)
                v = constrain(v, "batch", None, None, None)
                a = L.sdpa(q, k, v, causal=True, window=window)
                a = constrain(a, "batch", None, None, None)
            else:
                a = L.sdpa(q, k, v, causal=True, window=window)
        a = a.reshape(B, S_total, cfg.q_dim) @ p["attn"]["o"]
        new_slice["k"] = _ring_scatter(k, S_total, cache_len)
        new_slice["v"] = _ring_scatter(v, S_total, cache_len)
        if cfg.hybrid:
            m, state = L.mamba_forward(p["mamba"], h, cfg,
                                       use_kernel=use_kernels,
                                       return_state=True,
                                       scan_impl=os.environ.get(
                                           "REPRO_SSM_SCAN", "seq"))
            new_slice.update(state)
            a = 0.5 * (a + m)
        x = x + a
        h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        if cfg.moe is not None:
            y, _ = L.moe_block(p["moe"], h2.reshape(B * S_total, -1), cfg)
            y = y.reshape(B, S_total, -1)
        else:
            y = L.swiglu(h2, p["gate"], p["up"], p["down"])
        return (x + y,), new_slice

    (x,), cache = _run_layers(params["layers"], (x,), body, cfg)
    if last_only:
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return logits, cache


# --------------------------------------------------------------------
# paged KV cache (block pool + block tables) — serving
# --------------------------------------------------------------------
#
# Layout: one pool of fixed-size blocks shared by every lane,
#   kp, vp      : (L, num_blocks + 1, block_size, Hk, hd)
# addressed through per-lane block tables (n_lanes, nb_max) int32 where
# entry j maps logical block j (positions j*bs .. j*bs+bs-1, identity
# layout — no ring) to a physical block, or -1 if unallocated.  The LAST
# pool row is a scratch block: masked (inactive-lane) writes land there
# with zero values, so colliding scatter indices stay deterministic.
# SSM/hybrid decode state is O(1) per lane and needs no paging:
#   conv, ssm   : (L, n_lanes, cw-1, di), (L, n_lanes, di, n)
# Block accounting (free list, table assembly) is host-side, in
# ``repro.serve.paged_cache``.

def init_paged_cache(cfg: ModelConfig, n_lanes: int, num_blocks: int,
                     block_size: int):
    dt = _dtype(cfg)
    Ln = cfg.num_layers
    cache = {}
    if _has_attn(cfg):
        hd = cfg.resolved_head_dim
        cache["kp"] = jnp.zeros(
            (Ln, num_blocks + 1, block_size, cfg.num_kv_heads, hd), dt)
        cache["vp"] = jnp.zeros(
            (Ln, num_blocks + 1, block_size, cfg.num_kv_heads, hd), dt)
    if _has_mamba(cfg):
        ssm = cfg.ssm
        di = cfg.d_inner
        cache["conv"] = jnp.zeros((Ln, n_lanes, ssm.conv_dim - 1, di), dt)
        cache["ssm"] = jnp.zeros((Ln, n_lanes, di, ssm.state_dim), dt)
    return cache


def decode_step_paged(params, cache, token, pos, cfg: ModelConfig,
                      tables, active, *, block_size: int):
    """One decode tick over the paged cache.

    token, pos, active : (B,) int32 / int32 / bool — B lanes in lockstep,
        each at its own absolute position; inactive lanes compute but
        write only zeros into the scratch block and keep their SSM state.
    tables : (B, nb_max) int32 physical-block table per lane (-1 = not
        allocated).  Returns (logits (B, V), new cache).

    Numerics match the dense per-request decode path bit-for-bit: the
    gathered (B, nb*bs, Hk, hd) cache view feeds the same
    ``decode_attention`` einsums, and slots beyond a lane's allocation
    carry kv_pos = -1, masking them to exact zeros in the softmax.
    """
    x = params["embed"][token][:, None, :] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    is_global = layer_is_global(cfg)
    B = token.shape[0]
    bs = block_size
    if _has_attn(cfg):
        nb = tables.shape[1]
        scratch = cache["kp"].shape[1] - 1
        blk = jnp.clip(pos // bs, 0, nb - 1)
        off = jnp.mod(pos, bs)
        phys = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]
        ok = active & (phys >= 0)
        phys_w = jnp.where(ok, phys, scratch)          # (B,)
        tab_c = jnp.where(tables >= 0, tables, scratch)
        slot_idx = jnp.arange(nb * bs, dtype=jnp.int32)
        valid = jnp.repeat(tables >= 0, bs, axis=1)    # (B, nb*bs)
        kv_pos = jnp.where(valid, slot_idx[None], -1)

    def body(x, scanned):
        p, g, cs = scanned
        new = {}
        if cfg.arch_type == "ssm":
            h = L.rms_norm(x, p["norm"], cfg.rms_eps)
            y, conv, ssm = L.mamba_decode(p["mamba"], h, cfg,
                                          cs["conv"], cs["ssm"])
            return x + y, {"conv": _mask_state(conv, cs["conv"], active),
                           "ssm": _mask_state(ssm, cs["ssm"], active)}
        h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
        k_new, v_new = L.project_kv_one(p["attn"], h, cfg, pos)
        # inactive / unallocated lanes scatter ZEROS into the shared
        # scratch block — identical colliding writes are deterministic
        k_w = jnp.where(ok[:, None, None], k_new[:, 0], 0)
        v_w = jnp.where(ok[:, None, None], v_new[:, 0], 0)
        kp = cs["kp"].at[phys_w, off].set(k_w)
        vp = cs["vp"].at[phys_w, off].set(v_w)
        new["kp"], new["vp"] = kp, vp
        k_cache = kp[tab_c].reshape(B, nb * bs, cfg.num_kv_heads, -1)
        v_cache = vp[tab_c].reshape(B, nb * bs, cfg.num_kv_heads, -1)
        window = None
        if cfg.sliding_window is not None:
            window = jnp.where(g, L.GLOBAL_WINDOW, cfg.sliding_window)
        a = L.decode_attention(p["attn"], h, cfg, k_cache, v_cache, pos,
                               window=window, kv_pos_of_slot=kv_pos)
        if cfg.hybrid:
            m, conv, ssm = L.mamba_decode(p["mamba"], h, cfg,
                                          cs["conv"], cs["ssm"])
            a = 0.5 * (a + m)
            new["conv"] = _mask_state(conv, cs["conv"], active)
            new["ssm"] = _mask_state(ssm, cs["ssm"], active)
        x = x + a
        h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        if cfg.moe is not None:
            y, _ = L.moe_block(p["moe"], h2.reshape(B, -1), cfg)
            y = y.reshape(B, 1, -1)
        else:
            y = L.swiglu(h2, p["gate"], p["up"], p["down"])
        return x + y, new

    x, new_cache = jax.lax.scan(body, x, (params["layers"], is_global, cache))
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x[:, 0] @ params["embed"].T
    else:
        logits = x[:, 0] @ params["lm_head"]
    return logits, new_cache


def prefill_chunk_paged(params, cache, tokens, pos0, cfg: ModelConfig,
                        table_row, lane: int, *, block_size: int):
    """Prefill one chunk of one lane's prompt into the paged cache.

    tokens : (1, Sc) chunk covering absolute positions
        [pos0, pos0 + Sc); blocks spanning that range must already be
        allocated in ``table_row`` ((nb_max,) int32, -1 = unallocated).
    lane : which per-lane SSM state row carries across chunks.

    Chunked prefill is exact: attention sees every previously-written
    position via the gathered cache, and the SSM chunk continues the
    carried (conv, ssm) state with the same f32 recurrence as one-shot
    prefill.  Returns (last-position logits (1, V), new cache).
    """
    B, Sc = tokens.shape
    x = params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    is_global = layer_is_global(cfg)
    positions = pos0 + jnp.arange(Sc, dtype=jnp.int32)
    if _has_attn(cfg):
        bs = block_size
        nb = table_row.shape[0]
        scratch = cache["kp"].shape[1] - 1
        blk = jnp.clip(positions // bs, 0, nb - 1)
        off = jnp.mod(positions, bs)
        phys = table_row[blk]
        phys_w = jnp.where(phys >= 0, phys, scratch)   # (Sc,)
        tab_c = jnp.where(table_row >= 0, table_row, scratch)
        slot_idx = jnp.arange(nb * bs, dtype=jnp.int32)
        kv_pos = jnp.where(jnp.repeat(table_row >= 0, bs),
                           slot_idx, -1)[None]         # (1, nb*bs)
        qpos = positions[None]                         # (1, Sc)

    def body(x, scanned):
        p, g, cs = scanned
        new = {}
        if cfg.arch_type == "ssm":
            h = L.rms_norm(x, p["norm"], cfg.rms_eps)
            y, st = L.mamba_forward_chunk(p["mamba"], h, cfg,
                                          cs["conv"][lane][None],
                                          cs["ssm"][lane][None])
            new["conv"] = cs["conv"].at[lane].set(st["conv"][0])
            new["ssm"] = cs["ssm"].at[lane].set(st["ssm"][0])
            return x + y, new
        h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
        q, k, v = L.qkv_project(p["attn"], h, cfg, positions)
        kp = cs["kp"].at[phys_w, off].set(k[0])
        vp = cs["vp"].at[phys_w, off].set(v[0])
        new["kp"], new["vp"] = kp, vp
        k_cache = kp[tab_c].reshape(1, nb * bs, cfg.num_kv_heads, -1)
        v_cache = vp[tab_c].reshape(1, nb * bs, cfg.num_kv_heads, -1)
        window = None
        if cfg.sliding_window is not None:
            window = jnp.where(g, L.GLOBAL_WINDOW, cfg.sliding_window)
        a = L.gathered_attention(q, k_cache, v_cache, qpos, kv_pos,
                                 window=window)
        a = a.reshape(B, Sc, cfg.q_dim) @ p["attn"]["o"]
        if cfg.hybrid:
            m, st = L.mamba_forward_chunk(p["mamba"], h, cfg,
                                          cs["conv"][lane][None],
                                          cs["ssm"][lane][None])
            new["conv"] = cs["conv"].at[lane].set(st["conv"][0])
            new["ssm"] = cs["ssm"].at[lane].set(st["ssm"][0])
            a = 0.5 * (a + m)
        x = x + a
        h2 = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        if cfg.moe is not None:
            if cfg.moe.dispatch == "grouped":
                y, _ = L.moe_block(p["moe"], h2, cfg)
            else:
                y, _ = L.moe_block(p["moe"], h2.reshape(B * Sc, -1), cfg)
                y = y.reshape(B, Sc, -1)
        else:
            y = L.swiglu(h2, p["gate"], p["up"], p["down"])
        return x + y, new

    x, new_cache = jax.lax.scan(body, x, (params["layers"], is_global, cache))
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = x[:, 0] @ params["embed"].T
    else:
        logits = x[:, 0] @ params["lm_head"]
    return logits, new_cache
