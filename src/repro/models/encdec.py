"""Encoder-decoder transformer (whisper-small backbone).

The audio frontend (mel + conv) is a STUB per assignment: the encoder
consumes precomputed frame embeddings (B, F, d) from input_specs().
Deviations from the original (noted in DESIGN.md): RMSNorm instead of
LayerNorm, RoPE self-attention positions instead of learned/sinusoidal.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _init_mlp(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {
        "up": L.dense_init(k1, (cfg.d_model, cfg.d_ff), dtype=dt),
        "up_b": jnp.zeros((cfg.d_ff,), dt),
        "down": L.dense_init(k2, (cfg.d_ff, cfg.d_model), dtype=dt),
        "down_b": jnp.zeros((cfg.d_model,), dt),
    }


def init_enc_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(k1, cfg, dt),
        "mlp_norm": jnp.zeros((cfg.d_model,), dt),
        "mlp": _init_mlp(k2, cfg, dt),
    }


def init_dec_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(k1, cfg, dt),
        "xattn_norm": jnp.zeros((cfg.d_model,), dt),
        "xattn": L.init_attention(k2, cfg, dt),
        "mlp_norm": jnp.zeros((cfg.d_model,), dt),
        "mlp": _init_mlp(k3, cfg, dt),
    }


def init_params(cfg: ModelConfig, key):
    dt = _dtype(cfg)
    ke, kd, kemb = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": L.dense_init(kemb, (cfg.vocab_size, cfg.d_model),
                              scale=0.02, dtype=dt),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }


def _mlp(p, x):
    return L.gelu_mlp(x, p["up"], p["up_b"], p["down"], p["down_b"])


def _cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """x (B,Sq,d) queries vs precomputed encoder k/v (B,F,Hk,hd)."""
    B, Sq, _ = x.shape
    hd = cfg.resolved_head_dim
    k, v = enc_kv
    q = (x @ p["q"]).reshape(B, Sq, cfg.num_heads, hd)
    out = L.sdpa(q, k, v, causal=False)
    return out.reshape(B, Sq, cfg.q_dim) @ p["o"]


def encode(params, frames, cfg: ModelConfig, *, use_kernels=False):
    """frames (B,F,d) stub embeddings -> encoder states (B,F,d)."""
    x = frames.astype(_dtype(cfg))
    F = x.shape[1]
    positions = jnp.arange(F)

    def body(x, p):
        h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
        x = x + L.attention(p["attn"], h, cfg, causal=False,
                            positions=positions, use_kernel=use_kernels)
        h = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        return x + _mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.rms_eps)


def enc_kv(p_xattn, enc_out, cfg: ModelConfig):
    """Project encoder states to cross-attention k/v (no RoPE)."""
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p_xattn["k"]).reshape(B, F, cfg.num_kv_heads, hd)
    v = (enc_out @ p_xattn["v"]).reshape(B, F, cfg.num_kv_heads, hd)
    return k, v


def decode_forward(params, tokens, enc_out, cfg: ModelConfig, *,
                   use_kernels=False, remat=True):
    """Teacher-forced decoder pass: tokens (B,S) -> logits (B,S,V)."""
    x = params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(x, p):
        h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
        x = x + L.attention(p["attn"], h, cfg, causal=True,
                            positions=positions, use_kernel=use_kernels)
        h = L.rms_norm(x, p["xattn_norm"], cfg.rms_eps)
        x = x + _cross_attention(p["xattn"], h, enc_kv(p["xattn"], enc_out, cfg), cfg)
        h = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        return x + _mlp(p["mlp"], h), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x @ params["embed"].T        # whisper ties embeddings


def loss_fn(params, batch, cfg: ModelConfig, *, use_kernels=False, remat=True):
    """batch: {"frames": (B,F,d), "tokens": (B,S)}."""
    enc_out = encode(params, batch["frames"], cfg, use_kernels=use_kernels)
    logits = decode_forward(params, batch["tokens"], enc_out, cfg,
                            use_kernels=use_kernels, remat=remat)
    pred = logits[:, :-1].astype(jnp.float32)
    tgt = batch["tokens"][:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# ------------------------------------------------------------------
# decode with cache
# ------------------------------------------------------------------

def init_cache(cfg: ModelConfig, params, frames, cache_len: int):
    """Runs the encoder and precomputes cross k/v.  Returns cache dict."""
    dt = _dtype(cfg)
    B = frames.shape[0]
    hd = cfg.resolved_head_dim
    enc_out = encode(params, frames, cfg)

    def per_layer(p):
        return enc_kv(p["xattn"], enc_out, cfg)

    xk, xv = jax.vmap(per_layer)(params["dec_layers"])   # (L,B,F,Hk,hd)
    Ln = cfg.num_layers
    return {
        "k": jnp.zeros((Ln, B, cache_len, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((Ln, B, cache_len, cfg.num_kv_heads, hd), dt),
        "xk": xk,
        "xv": xv,
    }


def decode_step(params, cache, token, pos, cfg: ModelConfig):
    """One decoder token against self-cache + cross-cache."""
    x = params["embed"][token][:, None, :] * jnp.asarray(
        math.sqrt(cfg.d_model), _dtype(cfg))
    C = cache["k"].shape[2]

    def body(x, scanned):
        p, ck, cv, xk, xv = scanned
        h = L.rms_norm(x, p["attn_norm"], cfg.rms_eps)
        k_new, v_new = L.project_kv_one(p["attn"], h, cfg, pos)
        slot = jnp.mod(pos, C)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k_new, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v_new, slot, axis=1)
        kv_pos = pos - jnp.mod(pos - jnp.arange(C), C)
        x = x + L.decode_attention(p["attn"], h, cfg, ck, cv, pos,
                                   kv_pos_of_slot=kv_pos)
        h = L.rms_norm(x, p["xattn_norm"], cfg.rms_eps)
        x = x + _cross_attention(p["xattn"], h, (xk, xv), cfg)
        h = L.rms_norm(x, p["mlp_norm"], cfg.rms_eps)
        x = x + _mlp(p["mlp"], h)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = x[:, 0] @ params["embed"].T
    return logits, {**cache, "k": nk, "v": nv}
