"""Scripted scenario library: named, deterministic generators that
compile to :class:`~repro.cluster.runtime.ClusterEvent` streams.

Benchmarks and tests run the *same* scenario by name — ``run_cluster``
accepts ``scenario="spot_churn"`` directly — so a scheduler refactor
that changes simulated behavior is caught by the golden-trace suite in
``tests/test_scenarios.py``.  Every generator is a pure function of its
keyword knobs (``spot_churn`` draws from a generator seeded by its
``seed`` knob), so the same knobs always compile to the same event
stream.

Registered scenarios and their knobs
------------------------------------
``baseline()``
    No events: the undisturbed fabric, the control arm of every sweep.
``bursty_congestion(start, period, burst, depth, extra_latency, count,
scope)``
    ``count`` congestion windows of ``burst`` seconds, one every
    ``period`` seconds starting at ``start``: bandwidth is multiplied by
    ``depth`` (< 1) and every hop pays ``extra_latency`` while a window
    is open.  ``scope`` picks which links suffer ("inter" squeezes only
    the cross-pod bottleneck of a :class:`Topology`).
``spot_churn(seed, rate, horizon, rejoin_after, start)``
    Poisson spot-instance churn: leave events with exponential
    inter-arrival gaps (``rate`` per simulated second, until
    ``horizon``), each followed ``rejoin_after`` seconds later by a join
    that restores capacity from the spare pool.  A leave re-homes the
    leaver's data shards to the surviving trainer (they are *not*
    returned as spares), so the number of spare streams provisioned
    bounds how many rejoins land — under-provision and the pool
    collapses, which is itself a scenario worth measuring.
``pod_partition(start, duration, residual, extra_latency)``
    The cross-pod links all but fail for ``duration`` seconds:
    bandwidth drops to ``residual`` of nominal and hops pay
    ``extra_latency`` — a fabric partition that intra-pod traffic never
    notices.
``flash_crowd_join(start, joins, spacing)``
    ``joins`` trainers join in quick succession (every ``spacing``
    seconds) — a flash crowd landing on the spare pool.  Joins beyond
    the spare capacity are no-ops.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.cluster.runtime import ClusterEvent

#: name -> generator; use :func:`register_scenario` to extend
SCENARIOS: Dict[str, Callable[..., List[ClusterEvent]]] = {}


def register_scenario(name: str):
    """Decorator: register a generator under ``name``.  Generators must
    be deterministic functions of their keyword arguments."""
    def deco(fn: Callable[..., List[ClusterEvent]]):
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = fn
        return fn
    return deco


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def build_scenario(name: str, **knobs) -> List[ClusterEvent]:
    """Compile the registered scenario ``name`` to its event stream."""
    try:
        gen = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; registered: "
                         f"{list_scenarios()}") from None
    return gen(**knobs)


@register_scenario("baseline")
def baseline() -> List[ClusterEvent]:
    return []


@register_scenario("bursty_congestion")
def bursty_congestion(*, start: float = 0.005, period: float = 0.02,
                      burst: float = 0.01, depth: float = 0.1,
                      extra_latency: float = 8e-3, count: int = 6,
                      scope: str = "inter") -> List[ClusterEvent]:
    if not 0.0 < depth:
        raise ValueError(f"depth must be positive, got {depth}")
    return [ClusterEvent(time=start + i * period, kind="fabric",
                         scope=scope, bw_scale=depth,
                         extra_latency=extra_latency, duration=burst)
            for i in range(count)]


@register_scenario("spot_churn")
def spot_churn(*, seed: int = 0, rate: float = 50.0, horizon: float = 0.06,
               rejoin_after: float = 0.015,
               start: float = 0.005) -> List[ClusterEvent]:
    if rate <= 0.0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    events: List[ClusterEvent] = []
    t = start
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon:
            break
        events.append(ClusterEvent(time=t, kind="leave"))
        events.append(ClusterEvent(time=t + rejoin_after, kind="join"))
    return events


@register_scenario("pod_partition")
def pod_partition(*, start: float = 0.02, duration: float = 0.03,
                  residual: float = 0.05,
                  extra_latency: float = 2e-2) -> List[ClusterEvent]:
    return [ClusterEvent(time=start, kind="fabric", scope="inter",
                         bw_scale=residual, extra_latency=extra_latency,
                         duration=duration)]


@register_scenario("flash_crowd_join")
def flash_crowd_join(*, start: float = 0.02, joins: int = 2,
                     spacing: float = 0.01) -> List[ClusterEvent]:
    return [ClusterEvent(time=start + i * spacing, kind="join")
            for i in range(joins)]


__all__ = ["SCENARIOS", "register_scenario", "list_scenarios",
           "build_scenario", "baseline", "bursty_congestion", "spot_churn",
           "pod_partition", "flash_crowd_join"]
