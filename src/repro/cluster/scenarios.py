"""Scripted scenario library: named, deterministic generators that
compile to :class:`~repro.cluster.runtime.ClusterEvent` streams.

Benchmarks and tests run the *same* scenario by name — ``run_cluster``
accepts ``scenario="spot_churn"`` directly — so a scheduler refactor
that changes simulated behavior is caught by the golden-trace suite in
``tests/test_scenarios.py``.  Every generator is a pure function of its
keyword knobs (``spot_churn`` draws from a generator seeded by its
``seed`` knob), so the same knobs always compile to the same event
stream.

Registered scenarios and their knobs
------------------------------------
``baseline()``
    No events: the undisturbed fabric, the control arm of every sweep.
``bursty_congestion(start, period, burst, depth, extra_latency, count,
scope)``
    ``count`` congestion windows of ``burst`` seconds, one every
    ``period`` seconds starting at ``start``: bandwidth is multiplied by
    ``depth`` (< 1) and every hop pays ``extra_latency`` while a window
    is open.  ``scope`` picks which links suffer ("inter" squeezes only
    the cross-pod bottleneck of a :class:`Topology`).
``spot_churn(seed, rate, horizon, rejoin_after, start)``
    Poisson spot-instance churn: leave events with exponential
    inter-arrival gaps (``rate`` per simulated second, until
    ``horizon``), each followed ``rejoin_after`` seconds later by a join
    that restores capacity from the spare pool.  A scripted leave is a
    *preemption*: the survivor briefly absorbs the leaver's data
    shards, then the absorbed streams are reclaimed into the spare
    pool along with the nodes, so churn returns the full capacity it
    took and rejoins can land indefinitely.  (Only autoscaler-scripted
    shrinks — deliberate consolidations — leave the union on the
    survivor; see ``runtime.ClusterEvent``.)
``pod_partition(start, duration, residual, extra_latency)``
    The cross-pod links all but fail for ``duration`` seconds:
    bandwidth drops to ``residual`` of nominal and hops pay
    ``extra_latency`` — a fabric partition that intra-pod traffic never
    notices.
``flash_crowd_join(start, joins, spacing)``
    ``joins`` trainers join in quick succession (every ``spacing``
    seconds) — a flash crowd landing on the spare pool.  Joins beyond
    the spare capacity are no-ops.

Co-scripted scenarios (node dynamics + fabric windows together)
---------------------------------------------------------------
``correlated_pod_failure(start, duration, factor, nodes, depth,
extra_latency, scope)``
    One pod fails together: its nodes compute ``factor``x slower *and*
    the fabric joining pods degrades (``depth`` bandwidth scale,
    ``extra_latency`` per hop) for the same window.  ``nodes`` are the
    afflicted pod's indices into the profile list handed to
    ``run_cluster`` (defaults match pod 1 of a 2-pod interleaved
    layout); ``scope`` defaults to ``domain:cluster`` — the level whose
    paths are the pods' uplinks (the ring across pods is bottlenecked by
    its slowest path, so degrading the level prices like degrading the
    one uplink).
``diurnal_congestion(start, period, depth, cycles, steps, scope)``
    Smooth periodic congestion: each ``period`` is cut into ``steps``
    piecewise-constant windows whose bandwidth scale traces a cosine
    from 1.0 down to ``depth`` and back — the diurnal load curve of a
    shared fabric, repeated ``cycles`` times.
``rack_flap(start, period, burst, depth, extra_latency, count, domain)``
    One rack's level-0 fabric oscillates: ``count`` windows of ``burst``
    seconds every ``period`` on the named leaf domain only (default
    ``p0r0`` — the first rack of a 3-level
    ``Topology.from_profiles(..., pod_bw=...)`` tree); every other
    domain keeps its nominal links.
``straggler_cascade(start, window, depth, extra_latency, nodes, factor,
slow_for, stagger, scope)``
    Stragglers inside a congestion window: the fabric degrades for
    ``window`` seconds and, while it is open, ``nodes`` slow down one
    after another (``stagger`` apart, each ``factor``x slower for
    ``slow_for`` seconds) — the compounded worst case where the wire
    and the workers degrade together.
``drifted_merge(start, factor, duration, nodes)``
    One trainer's nodes slow hard enough that its round counter drifts
    past ``merge_drift_window`` by the first merge round: the
    round-tagged merge fires on time among the up-to-date trainers and
    records the laggard in the ``skipped`` list instead of stalling.

Adaptive-aware scenarios (run with ``acfg.adaptive=True``)
----------------------------------------------------------
These two are the adaptive-batching arms of the sweep: the *ramp* is
driven by the config (requested batches grow via the paper's §3.3
tests, so every round ends in a priced batch-stats reduction and the
per-round roofline compute grows with the batch), and the scenario
supplies the fabric the ramp runs on.

``adaptive_ramp()``
    No events: the undisturbed fabric — the control arm, isolating the
    cost/benefit of batch growth itself (stats collectives + growing
    compute vs fewer rounds to target).
``autoscale_ramp()``
    No events, like ``adaptive_ramp`` — but meant to run with a
    ``ClusterSpec.autoscale`` policy (see ``repro.cluster.autoscale``):
    the batch ramp drives the pool, joins and leaves are scripted by the
    autoscaler at round boundaries rather than by the event stream.
``preemption_storm_growth(start, leaves, spacing)``
    A burst of trainer evictions timed to land mid-growth; with an
    autoscale policy the band re-grows the pool from the spares, paying
    real join-transfer prices through the re-pricing registry.
``congested_adaptive(start, duration, depth, extra_latency, scope)``
    One deep congestion window timed to collide with the batch ramp —
    the paper's motivating trade: exactly as rounds lengthen (growing
    batches) and outer payloads matter most, the fabric degrades, and
    the stats reductions in flight are re-priced along with the outer
    syncs.  The default window opens early enough that a fixed-batch
    control arm of the same length also runs through it (both arms of
    the bench sweep see the same weather; see ``benchmarks/
    cluster_bench.py``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

from repro.cluster.runtime import ClusterEvent

#: name -> generator; use :func:`register_scenario` to extend
SCENARIOS: Dict[str, Callable[..., List[ClusterEvent]]] = {}


@dataclass(frozen=True)
class Scenario:
    """A compiled scenario: the generator's name, the knobs it was built
    with, and the event stream they compiled to.

    Behaves as a plain sequence of :class:`~repro.cluster.runtime.
    ClusterEvent`\\ s (iteration, ``len``, indexing, slicing, ``+`` with
    a list concatenates to a raw event list), so every call site that
    accepted a raw list still works — but the *name* now travels with
    the events, and ``run_cluster`` threads it into
    ``ClusterReport.summary(extended=True)``.
    """

    name: str
    knobs: Dict[str, Any] = field(default_factory=dict)
    events: Tuple[ClusterEvent, ...] = ()

    def __len__(self) -> int:
        return len(self.events)

    def __getitem__(self, idx):
        return self.events[idx]

    def __iter__(self):
        return iter(self.events)

    def __add__(self, other) -> List[ClusterEvent]:
        return list(self.events) + list(other)

    def __radd__(self, other) -> List[ClusterEvent]:
        return list(other) + list(self.events)


def register_scenario(name: str):
    """Decorator: register a generator under ``name``.  Generators must
    be deterministic functions of their keyword arguments."""
    def deco(fn: Callable[..., List[ClusterEvent]]):
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = fn
        return fn
    return deco


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def build_scenario(name: str, **knobs) -> Scenario:
    """Compile the registered scenario ``name`` to a named
    :class:`Scenario` record (a sequence of its events)."""
    try:
        gen = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; registered: "
                         f"{list_scenarios()}") from None
    return Scenario(name=name, knobs=dict(knobs), events=tuple(gen(**knobs)))


@register_scenario("baseline")
def baseline() -> List[ClusterEvent]:
    return []


@register_scenario("bursty_congestion")
def bursty_congestion(*, start: float = 0.005, period: float = 0.02,
                      burst: float = 0.01, depth: float = 0.1,
                      extra_latency: float = 8e-3, count: int = 6,
                      scope: str = "inter") -> List[ClusterEvent]:
    if not 0.0 < depth:
        raise ValueError(f"depth must be positive, got {depth}")
    return [ClusterEvent(time=start + i * period, kind="fabric",
                         scope=scope, bw_scale=depth,
                         extra_latency=extra_latency, duration=burst)
            for i in range(count)]


@register_scenario("spot_churn")
def spot_churn(*, seed: int = 0, rate: float = 50.0, horizon: float = 0.06,
               rejoin_after: float = 0.015,
               start: float = 0.005) -> List[ClusterEvent]:
    if rate <= 0.0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    events: List[ClusterEvent] = []
    t = start
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon:
            break
        events.append(ClusterEvent(time=t, kind="leave"))
        events.append(ClusterEvent(time=t + rejoin_after, kind="join"))
    return events


@register_scenario("pod_partition")
def pod_partition(*, start: float = 0.02, duration: float = 0.03,
                  residual: float = 0.05,
                  extra_latency: float = 2e-2) -> List[ClusterEvent]:
    return [ClusterEvent(time=start, kind="fabric", scope="inter",
                         bw_scale=residual, extra_latency=extra_latency,
                         duration=duration)]


@register_scenario("flash_crowd_join")
def flash_crowd_join(*, start: float = 0.02, joins: int = 2,
                     spacing: float = 0.01) -> List[ClusterEvent]:
    return [ClusterEvent(time=start + i * spacing, kind="join")
            for i in range(joins)]


@register_scenario("correlated_pod_failure")
def correlated_pod_failure(*, start: float = 0.01, duration: float = 0.03,
                           factor: float = 3.0, nodes=(1, 3, 5),
                           depth: float = 0.15, extra_latency: float = 6e-3,
                           scope: str = "domain:cluster"
                           ) -> List[ClusterEvent]:
    if not 0.0 < depth:
        raise ValueError(f"depth must be positive, got {depth}")
    evs = [ClusterEvent(time=start, kind="slowdown", node=int(i),
                        factor=factor, duration=duration)
           for i in nodes]
    evs.append(ClusterEvent(time=start, kind="fabric", scope=scope,
                            bw_scale=depth, extra_latency=extra_latency,
                            duration=duration))
    return evs


@register_scenario("diurnal_congestion")
def diurnal_congestion(*, start: float = 0.0, period: float = 0.04,
                       depth: float = 0.25, cycles: int = 2,
                       steps: int = 8,
                       scope: str = "inter") -> List[ClusterEvent]:
    if not 0.0 < depth <= 1.0:
        raise ValueError(f"depth must be in (0, 1], got {depth}")
    if steps < 1 or cycles < 1:
        raise ValueError(f"steps and cycles must be >= 1, got "
                         f"{steps}/{cycles}")
    dt = period / steps
    evs = []
    for c in range(cycles):
        for s in range(steps):
            # midpoint of the step on the cosine load curve: scale 1.0
            # at the period edges, `depth` at its trough
            phase = (s + 0.5) / steps
            scale = depth + (1.0 - depth) * 0.5 * (
                1.0 + math.cos(2.0 * math.pi * phase))
            evs.append(ClusterEvent(time=start + (c * steps + s) * dt,
                                    kind="fabric", scope=scope,
                                    bw_scale=scale, duration=dt))
    return evs


@register_scenario("rack_flap")
def rack_flap(*, start: float = 0.004, period: float = 0.016,
              burst: float = 0.008, depth: float = 0.1,
              extra_latency: float = 4e-3, count: int = 5,
              domain: str = "p0r0") -> List[ClusterEvent]:
    if not 0.0 < depth:
        raise ValueError(f"depth must be positive, got {depth}")
    return [ClusterEvent(time=start + i * period, kind="fabric",
                         scope=f"domain:{domain}", bw_scale=depth,
                         extra_latency=extra_latency, duration=burst)
            for i in range(count)]


@register_scenario("straggler_cascade")
def straggler_cascade(*, start: float = 0.01, window: float = 0.04,
                      depth: float = 0.2, extra_latency: float = 5e-3,
                      nodes=(0, 2, 4), factor: float = 4.0,
                      slow_for: float = 0.02, stagger: float = 0.006,
                      scope: str = "inter") -> List[ClusterEvent]:
    if not 0.0 < depth:
        raise ValueError(f"depth must be positive, got {depth}")
    evs = [ClusterEvent(time=start, kind="fabric", scope=scope,
                        bw_scale=depth, extra_latency=extra_latency,
                        duration=window)]
    for i, n in enumerate(nodes):
        t = start + (i + 1) * stagger
        if t >= start + window:      # cascade stays inside the window
            break
        evs.append(ClusterEvent(time=t, kind="slowdown", node=int(n),
                                factor=factor, duration=slow_for))
    return evs


@register_scenario("drifted_merge")
def drifted_merge(*, start: float = 0.0, factor: float = 8.0,
                  duration: float = 10.0, nodes=(2, 3)) -> List[ClusterEvent]:
    """Drift one trainer past the merge window: the given nodes (default
    trainer 1's pair in a k=3, M=2 layout) compute ``factor``x slower
    from ``start``, so by the first merge round that trainer's round
    counter lags the callers'.  Round-tagged merging fires ON TIME and
    skips the drifted trainer (``merge_drift_window``) instead of the
    old behavior — stalling every merge until the slowest trainer
    caught up and then folding rounds-stale params into the pool.
    Pinned by the GOLDENM golden in ``tests/test_scenarios.py``."""
    return [ClusterEvent(time=start, kind="slowdown", node=int(n),
                         factor=factor, duration=duration)
            for n in nodes]


@register_scenario("adaptive_ramp")
def adaptive_ramp() -> List[ClusterEvent]:
    """Clean fabric for the batch ramp (see the module docstring): the
    adaptivity lives in the config, not the event stream."""
    return []


@register_scenario("autoscale_ramp")
def autoscale_ramp() -> List[ClusterEvent]:
    """Clean fabric for the batch-growth *autoscaling* arm: like
    ``adaptive_ramp`` the adaptivity lives in the config, and the pool
    dynamics live in the ``ClusterSpec.autoscale`` policy (joins/leaves
    are scripted by the autoscaler at round boundaries, not by the event
    stream), so the scenario itself contributes no events."""
    return []


@register_scenario("preemption_storm_growth")
def preemption_storm_growth(*, start: float = 0.08, leaves: int = 2,
                            spacing: float = 0.02) -> List[ClusterEvent]:
    """A burst of preemptions timed to land mid-growth: ``leaves``
    trainers are evicted every ``spacing`` seconds starting at ``start``
    (defaults hit the exponential phase of the adaptive ramp).  Run with
    an autoscale policy: the band detects the collapsed pool against the
    still-large batch and re-grows from the spare pool, paying real
    join-transfer prices.  Each eviction returns the leaver's streams
    and nodes to the spares, so the storm never permanently shrinks the
    join capacity — the bench gates the gradients-per-worker band
    re-closing after the last eviction."""
    return [ClusterEvent(time=start + i * spacing, kind="leave")
            for i in range(leaves)]


@register_scenario("congested_adaptive")
def congested_adaptive(*, start: float = 0.015, duration: float = 0.12,
                       depth: float = 0.1, extra_latency: float = 8e-3,
                       scope: str = "inter") -> List[ClusterEvent]:
    if not 0.0 < depth:
        raise ValueError(f"depth must be positive, got {depth}")
    return [ClusterEvent(time=start, kind="fabric", scope=scope,
                         bw_scale=depth, extra_latency=extra_latency,
                         duration=duration)]


__all__ = ["SCENARIOS", "Scenario", "register_scenario", "list_scenarios",
           "build_scenario", "baseline", "bursty_congestion", "spot_churn",
           "pod_partition", "flash_crowd_join", "correlated_pod_failure",
           "diurnal_congestion", "rack_flap", "straggler_cascade",
           "adaptive_ramp", "autoscale_ramp", "congested_adaptive",
           "drifted_merge", "preemption_storm_growth"]
