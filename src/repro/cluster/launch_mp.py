"""Multi-process launcher: the cluster runtime on real ``jax.distributed``.

Spawns one OS process per worker on this host, initializes the
``jax.distributed`` coordination service (process 0 is the coordinator,
and its results are the run's results), and drives the *same*
``run_cluster`` event loop as the simulator — with a
:class:`~repro.cluster.backend.JaxProcessBackend`, so every outer
all-reduce executes as a real ``jax.lax`` collective across processes
instead of being priced analytically.  Every process runs the identical
deterministic event loop (pricing is pure float arithmetic on replicated
state), computes only its own worker's inner steps, and meets the others
inside the collectives; process 0 writes the report.

The canonical workload is the same 16-dim quadratic the test-suite
fixtures use (one trainer, M = nprocs workers, fixed batch), which is
what makes the sim/real differential guarantee checkable:

    # one sync outer round over 2 local CPU processes + parity check
    PYTHONPATH=src python -m repro.cluster.launch_mp \\
        --procs 2 --rounds 1 --check

    # async policy on a 2-pod topology (hierarchical process groups)
    PYTHONPATH=src python -m repro.cluster.launch_mp \\
        --procs 2 --rounds 8 --policy async --pods

``--check`` re-runs the identical fixture through the in-process
:class:`~repro.cluster.backend.SimBackend` and asserts the final
parameters match to float tolerance — the contract
``tests/test_backend.py`` pins in CI.

``--adaptive`` switches the fixture to adaptive batching + switch mode
(``stats_estimator="microbatch"``): each rank contributes its worker's
microbatch-mean gradient to the batch-stats all-reduce (real
``lax.pmean`` phases over the mesh), every rank derives the identical
requested-batch/plan sequence (divergence is a hard failure, checked by
allgather), and ``--check`` pins the whole trajectory — params, batch
sizes, modes — against the SimBackend reference::

    PYTHONPATH=src python -m repro.cluster.launch_mp \\
        --procs 2 --rounds 6 --adaptive --check

``--k-correct N`` (with ``--adaptive``) enables the PadaDamp-style
batch-growth predictor: between every N-th exact estimate the ranks
*predict* the next batch from the fitted growth curve instead of
running the batch-stats all-reduce, so most rounds issue zero stats
collectives — and the decision-agreement guarantee must hold anyway,
because every rank fits the same curve to the same observations.
``--check`` pins that trajectory against the SimBackend reference.

Outer collectives are *dispatched* nonblocking (``dispatch_outer`` /
``wait_outer``): under ``--policy async`` the next round's inner steps
run while the reduction is in flight, and under ``--adaptive`` the
phase-1 batch-stats vector rides the same fused collective
(piggybacking).  ``--trace`` records the measured dispatch->ready
windows alongside the noted compute windows, and ``--check`` on async
runs additionally gates ``real_overlap_frac > 0`` — wall-clock proof
the overlap is real, not simulated.

``--k N`` splits the processes into N trainer groups of
``procs // N`` workers each (MIT, paper §4.1): each trainer's outer
sync is a grouped collective over its own block of ranks, and
``--merge`` turns on merge events — executed as real cross-group
weighted psums — so the paper's three-stage method runs end-to-end on
real collectives.  ``--check`` then also pins the merge applied-events
against the SimBackend reference::

    PYTHONPATH=src python -m repro.cluster.launch_mp \\
        --procs 4 --k 2 --rounds 6 --merge --check

Scope: sync/async policies; multi-trainer pools are fixed-batch (the
stats reductions are global, not per-group — see
``JaxProcessBackend.validate``).  The per-sample probe estimator stays
rejected under multi-process adaptive runs (its probe is rank-local);
elastic pools (joins/leaves/autoscale) stay simulator-only.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import List, Optional

import numpy as np

#: toy-scale hardware constants shared with the bench/test fixtures so
#: compute and comm land in comparable (simulated) regimes
TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)

DIM = 16


class _QuadStream:
    """Deterministic least-squares stream, numerically identical to the
    test-suite/bench QuadStream (same SeedSequence scheme)."""

    def __init__(self, prob, shard: int, seed: int = 0):
        self.prob = prob
        self.rng = np.random.default_rng(
            np.random.SeedSequence([seed, shard]))

    def next_batch(self, b):
        A, y = self.prob.sample(b, self.rng)
        return {"A": A, "y": y}


def quad_loss(params, batch):
    import jax.numpy as jnp
    r = batch["A"] @ params["x"] - batch["y"]
    return 0.5 * jnp.mean(jnp.square(r)), {}


def fixture(procs: int, *, rounds: int, pods: bool = False, seed: int = 0,
            adaptive: bool = False, k_correct: int = 0, k: int = 1,
            merge: bool = False):
    """(acfg, inits, streams, profiles, network) for the canonical run:
    ``k`` trainers x ``procs // k`` workers (the default is the single
    trainer with M = ``procs`` workers, merging off).  ``pods`` splits
    the workers across a 2-pod :class:`Topology` so the hierarchical
    group mapping is exercised; otherwise the fabric is the flat
    :class:`NetworkModel`.  ``adaptive`` swaps the fixed batch for
    adaptive batching + switch mode with the composable microbatch
    estimator (``max_batch`` small enough that the ramp crosses the
    switch boundary within a handful of rounds); ``k_correct > 1``
    additionally turns on predicted batch growth between exact
    estimates.  ``merge`` enables MIT merge events (every 3rd round,
    ``merge_w + 1 = 2`` smallest-batch trainers fold into their
    representative)."""
    import dataclasses

    import jax
    from repro.configs.base import AdLoCoConfig
    from repro.data import QuadraticProblem
    from repro.cluster.network import NetworkModel, Topology
    from repro.cluster.node import (interleave_pods,
                                    make_heterogeneous_profiles,
                                    make_pod_profiles)

    if procs % k != 0:
        raise ValueError(f"--k {k} must divide --procs {procs}")
    M = procs // k
    acfg = AdLoCoConfig(num_outer_steps=rounds, num_inner_steps=5,
                        lr_inner=0.05, lr_outer=0.7, outer_momentum=0.5,
                        nodes_per_gpu=M, num_init_trainers=k,
                        initial_batch_size=4, merge_frequency=3, eta=0.8,
                        max_batch=16, inner_optimizer="sgd",
                        stats_probe_size=32, enable_merge=merge,
                        adaptive=False)
    if adaptive:
        acfg = dataclasses.replace(
            acfg, adaptive=True, stats_estimator="microbatch",
            eta=0.25, max_batch=8, switch_multiplier=2,
            max_global_batch=64, k_correct=max(1, k_correct))
    prob = QuadraticProblem(dim=DIM, noise=2.0, seed=seed)
    inits = [{"x": jax.random.normal(jax.random.PRNGKey(seed + i), (DIM,))}
             for i in range(k)]
    streams = [_QuadStream(prob, i, seed=seed) for i in range(procs)]
    if pods and procs >= 2:
        profiles = make_pod_profiles(
            [procs - procs // 2, procs // 2], ratio=2.0, **TOY)
        profiles = interleave_pods(profiles)
        network = Topology.from_profiles(profiles, inter_bw=1e5,
                                         inter_latency=4e-3)
    else:
        profiles = make_heterogeneous_profiles(procs, ratio=2.0, **TOY)
        network = NetworkModel()
    return acfg, inits, streams, profiles, network


def merge_events_of(rep) -> List[dict]:
    """The merge-related applied events (executed and skipped) — the
    MIT trajectory the parity check pins across backends."""
    return [e for e in rep.applied_events
            if e.get("kind") in ("merge", "merge_skipped")]


def run_sim(procs: int, *, rounds: int, policy: str = "sync",
            pods: bool = False, seed: int = 0, adaptive: bool = False,
            k_correct: int = 0, k: int = 1, merge: bool = False,
            trace: bool = False):
    """The same fixture through the in-process SimBackend — the
    reference arm of the parity check.  ``trace`` records the span
    trace and adds its backend-invariant ``trace_digest`` (the
    sim-span digest the real run must reproduce)."""
    from repro.cluster.backend import SimBackend
    from repro.cluster.runtime import run_cluster

    acfg, inits, streams, profiles, network = fixture(
        procs, rounds=rounds, pods=pods, seed=seed, adaptive=adaptive,
        k_correct=k_correct, k=k, merge=merge)
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy=policy, profiles=profiles,
        backend=SimBackend(network), trace=trace or None,
        fixed_batch=None if adaptive else 4)
    res = {"x": np.asarray(pool.global_params["x"], np.float64).tolist(),
           "sim_time": rep.sim_time, "comm_time": rep.comm_time,
           "num_syncs": rep.num_syncs,
           "num_stats_syncs": rep.num_stats_syncs,
           "batches": hist.requested_batches, "modes": hist.modes,
           "merge_events": merge_events_of(rep),
           "policy": policy, "procs": procs, "k": k,
           "merge": bool(merge), "backend": "sim"}
    if rep.trace is not None:
        res["trace_digest"] = rep.trace.sim_digest()
        res["overlap_frac"] = rep.trace.overlap_fraction()
        res["utilization"] = rep.trace.utilization_summary()["utilization"]
    return res


# --------------------------------------------------------------- worker

def worker_main(args) -> int:
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax
    try:
        # cross-process CPU collectives need a real transport
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:                    # older jaxlibs: single transport
        pass
    jax.distributed.initialize(coordinator_address=args.coordinator,
                               num_processes=args.procs,
                               process_id=args.rank)
    from jax.experimental import multihost_utils

    from repro.cluster.backend import JaxProcessBackend
    from repro.cluster.runtime import run_cluster

    acfg, inits, streams, profiles, network = fixture(
        args.procs, rounds=args.rounds, pods=args.pods, seed=args.seed,
        adaptive=args.adaptive, k_correct=args.k_correct, k=args.k,
        merge=args.merge)
    backend = JaxProcessBackend(network)
    # every rank builds the same seeded inits; the broadcast makes the
    # coordinator's copies authoritative (and exercises the transfer
    # path) — one broadcast per trainer, lockstep on every rank
    inits = [backend.broadcast_params(p) for p in inits]

    # every rank records (the event loop is lockstep, so the sim spans
    # are identical everywhere); only rank 0 exports
    record = bool(args.trace) or args.record_trace

    t0 = time.perf_counter()
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy=args.policy,
        profiles=profiles, backend=backend, trace=record or None,
        fixed_batch=None if args.adaptive else 4)
    wall = time.perf_counter() - t0

    # the collectives must have left every rank with identical params
    x = np.asarray(pool.global_params["x"], np.float64)
    gathered = np.asarray(multihost_utils.process_allgather(
        pool.global_params["x"]))
    if not np.allclose(gathered, gathered[0], rtol=0, atol=1e-6):
        print(f"[rank {args.rank}] parameter divergence across ranks: "
              f"{gathered}", file=sys.stderr)
        return 3

    # shape agreement: every rank must have derived the identical
    # batch/plan trajectory (the BatchPlanProtocol contract — a single
    # diverged compiled shape would already have deadlocked the
    # collectives, but check the decision sequence explicitly)
    import jax.numpy as jnp
    traj = np.asarray([[b[0], 0 if m[0] == "plain" else 1]
                       for b, m in zip(hist.requested_batches, hist.modes)],
                      np.int32)
    all_traj = np.asarray(multihost_utils.process_allgather(
        jnp.asarray(traj)))
    if all_traj.size and not (all_traj == all_traj[0]).all():
        print(f"[rank {args.rank}] batch/plan trajectory divergence "
              f"across ranks: {all_traj.tolist()}", file=sys.stderr)
        return 4

    if args.rank == 0 and args.out:
        result = {"x": x.tolist(), "sim_time": rep.sim_time,
                  "comm_time": rep.comm_time,
                  "real_comm_time": rep.real_comm_time,
                  "num_syncs": rep.num_syncs,
                  "num_stats_syncs": rep.num_stats_syncs,
                  "batches": hist.requested_batches, "modes": hist.modes,
                  "merge_events": merge_events_of(rep),
                  "rounds": dict(rep.rounds), "loss": hist.loss,
                  "policy": args.policy, "procs": args.procs,
                  "pods": bool(args.pods), "wall_s": wall,
                  "adaptive": bool(args.adaptive),
                  "k_correct": int(args.k_correct),
                  "k": int(args.k), "merge": bool(args.merge),
                  "backend": "jax"}
        if rep.trace is not None:
            reals = rep.trace.real_spans()
            result["trace_digest"] = rep.trace.sim_digest()
            result["overlap_frac"] = rep.trace.overlap_fraction()
            # measured wall-clock overlap: dispatched collective windows
            # (dispatch -> ready) coincident with real inner compute —
            # nonzero only when the backend is actually nonblocking
            result["real_overlap_frac"] = rep.trace.overlap_fraction(
                clock="real")
            result["utilization"] = (
                rep.trace.utilization_summary()["utilization"])
            result["num_real_spans"] = len(reals)
            result["real_span_time"] = sum(
                s.duration for s in reals if s.kind != "compute")
            if args.trace:
                with open(args.trace, "w") as f:
                    json.dump(rep.trace.to_perfetto(), f)
        with open(args.out, "w") as f:
            json.dump(result, f)
    jax.distributed.shutdown()
    return 0


# --------------------------------------------------------------- parent

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_mp(procs: int, *, rounds: int = 2, policy: str = "sync",
           pods: bool = False, seed: int = 0, adaptive: bool = False,
           k_correct: int = 0, k: int = 1, merge: bool = False,
           trace: Optional[str] = None,
           record_trace: bool = False, timeout: float = 600.0) -> dict:
    """Spawn ``procs`` local worker processes, run the fixture through
    the real backend, and return process 0's result dict.  ``trace``
    names a Perfetto JSON path for rank 0 to export; ``record_trace``
    records spans (digest + real wall-time stats in the result dict)
    without writing a file."""
    coord = f"127.0.0.1:{_free_port()}"
    out = tempfile.NamedTemporaryFile(suffix=".json", delete=False)
    out.close()
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    # one device per process — the JaxProcessBackend mesh contract
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")
    children: List[subprocess.Popen] = []
    try:
        for rank in range(procs):
            cmd = [sys.executable, "-m", "repro.cluster.launch_mp",
                   "--worker", "--rank", str(rank), "--procs", str(procs),
                   "--coordinator", coord, "--rounds", str(rounds),
                   "--policy", policy, "--seed", str(seed),
                   "--k-correct", str(k_correct), "--k", str(k),
                   "--out", out.name]
            if pods:
                cmd.append("--pods")
            if adaptive:
                cmd.append("--adaptive")
            if merge:
                cmd.append("--merge")
            if trace and rank == 0:
                cmd.extend(["--trace", trace])
            elif trace or record_trace:
                cmd.append("--record-trace")
            children.append(subprocess.Popen(
                cmd, env=env, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True))
        deadline = time.time() + timeout
        tails = {}
        for rank, ch in enumerate(children):
            left = max(1.0, deadline - time.time())
            try:
                tails[rank], _ = ch.communicate(timeout=left)
            except subprocess.TimeoutExpired:
                for c in children:
                    c.kill()
                raise RuntimeError(
                    f"launch_mp rank {rank} timed out after {timeout}s")
        bad = [r for r, ch in enumerate(children) if ch.returncode != 0]
        if bad:
            detail = "\n".join(
                f"--- rank {r} (exit {children[r].returncode}) ---\n"
                f"{tails[r][-2000:]}" for r in bad)
            raise RuntimeError(f"launch_mp workers failed:\n{detail}")
        with open(out.name) as f:
            return json.load(f)
    finally:
        try:
            os.unlink(out.name)
        except OSError:
            pass


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--procs", type=int, default=2,
                    help="local worker processes (= workers per trainer)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="outer rounds to run")
    ap.add_argument("--policy", choices=("sync", "async"), default="sync")
    ap.add_argument("--pods", action="store_true",
                    help="2-pod Topology (hierarchical process groups) "
                         "instead of the flat NetworkModel")
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive batching + switch mode (microbatch "
                         "estimator; batch-stats all-reduce over the "
                         "mesh) instead of the fixed batch")
    ap.add_argument("--k-correct", type=int, default=0, dest="k_correct",
                    help="with --adaptive: run the exact batch-stats "
                         "reduction only every Nth round and predict "
                         "the batch from the fitted growth curve in "
                         "between (0/1 = exact every round)")
    ap.add_argument("--k", type=int, default=1,
                    help="trainer groups: split the processes into k "
                         "disjoint groups of procs//k workers each "
                         "(MIT multi-instance pool; must divide --procs)")
    ap.add_argument("--merge", action="store_true",
                    help="with --k > 1: enable MIT merge events, "
                         "executed as real cross-group collectives")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="also run the SimBackend reference in-process "
                         "and assert final-parameter parity (plus "
                         "sim-span trace-digest parity when tracing)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the span trace and write rank 0's "
                         "Perfetto JSON here (wall-clock collective "
                         "spans alongside the sim spans)")
    ap.add_argument("--out", default=None, help="write rank-0 result JSON")
    ap.add_argument("--timeout", type=float, default=600.0)
    # internal: worker mode (spawned by run_mp)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--rank", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--record-trace", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.adaptive and args.k > 1:
        ap.error("--adaptive needs --k 1 (the batch-stats reductions "
                 "are global, not per trainer group)")
    if args.worker:
        return worker_main(args)

    res = run_mp(args.procs, rounds=args.rounds, policy=args.policy,
                 pods=args.pods, seed=args.seed, adaptive=args.adaptive,
                 k_correct=args.k_correct, k=args.k, merge=args.merge,
                 trace=args.trace,
                 record_trace=args.check, timeout=args.timeout)
    n_merges = sum(1 for e in res.get("merge_events", ())
                   if e["kind"] == "merge")
    print(f"[launch_mp] procs={res['procs']} k={res['k']} "
          f"policy={res['policy']} "
          f"pods={res['pods']} adaptive={res['adaptive']} "
          f"syncs={res['num_syncs']} stats={res['num_stats_syncs']} "
          f"merges={n_merges} "
          f"sim_time={res['sim_time']:.4f}s "
          f"real_comm={res['real_comm_time']:.4f}s "
          f"wall={res['wall_s']:.2f}s")
    if "trace_digest" in res:
        print(f"[launch_mp] trace: digest={res['trace_digest']} "
              f"overlap_frac={res['overlap_frac']:.4f} "
              f"real_overlap_frac={res['real_overlap_frac']:.4f} "
              f"utilization={res['utilization']:.4f} "
              f"real_spans={res['num_real_spans']} "
              f"({res['real_span_time']:.6f}s wall)"
              + (f" -> {args.trace}" if args.trace else ""))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f)
    if args.check:
        traced = "trace_digest" in res
        ref = run_sim(args.procs, rounds=args.rounds, policy=args.policy,
                      pods=args.pods, seed=args.seed,
                      adaptive=args.adaptive, k_correct=args.k_correct,
                      k=args.k, merge=args.merge, trace=traced)
        diff = float(np.max(np.abs(np.asarray(res["x"])
                                   - np.asarray(ref["x"]))))
        same_clock = (res["sim_time"] == ref["sim_time"]
                      and res["num_syncs"] == ref["num_syncs"])
        same_plan = (res["batches"] == ref["batches"]
                     and res["modes"] == ref["modes"])
        # the merge trajectory (executed + skipped events, with their
        # rounds and participants) must match the simulator exactly;
        # with --merge at least one merge must actually have executed
        # or the cross-group collective path wasn't exercised
        same_merges = (res.get("merge_events") == ref.get("merge_events"))
        merged_ok = (not args.merge
                     or any(e["kind"] == "merge"
                            for e in res.get("merge_events", ())))
        # the sim-span digest must be backend-invariant, and the real
        # backend must have measured actual wall time on the wire
        same_trace = (not traced
                      or res["trace_digest"] == ref["trace_digest"])
        real_ok = not traced or res["real_span_time"] > 0.0
        # nonblocking contract: on async runs the dispatched outer
        # collective must measurably overlap real inner compute — a
        # wall-clock fact, not a property of the simulated schedule
        overlap_ok = (not traced or args.policy != "async"
                      or res["real_overlap_frac"] > 0.0)
        print(f"[launch_mp] parity vs SimBackend: max|dx|={diff:.3e} "
              f"same_sim_clock={same_clock} same_plan_seq={same_plan} "
              f"same_merge_events={same_merges} merged_ok={merged_ok} "
              f"same_trace_digest={same_trace} real_spans_ok={real_ok} "
              f"real_overlap_ok={overlap_ok}")
        if (diff > 1e-5 or not same_clock or not same_plan
                or not same_merges or not merged_ok
                or not same_trace or not real_ok or not overlap_ok):
            print("[launch_mp] PARITY FAILURE", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
