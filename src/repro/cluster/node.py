"""Virtual node profiles for the cluster runtime.

A :class:`NodeProfile` is a two-term roofline (compute / HBM) plus a link
spec, derived by default from the TPU v5e constants in
``repro.launch.roofline``.  Heterogeneity is expressed as a per-node
``speed`` scale (flops, HBM and link bandwidth all scale together — a
slow node is slow end to end), stragglers as lognormal jitter on every
round's compute time, and scheduled degradations as time-windowed
slowdown factors.
"""
from __future__ import annotations

import itertools
import zlib
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS

#: default per-hop link latency (s) — ICI-class interconnect
DEFAULT_LATENCY = 1e-6


@dataclass
class Slowdown:
    """Compute runs ``factor``x slower inside [start, end)."""

    start: float
    end: float
    factor: float


@dataclass
class NodeProfile:
    name: str
    flops: float                    # peak FLOP/s
    hbm_bw: float                   # bytes/s
    link_bw: float                  # bytes/s on this node's NIC/ICI link
    link_latency: float = DEFAULT_LATENCY
    jitter: float = 0.0             # lognormal sigma on compute time
    seed: int = 0
    pod: Optional[int] = None       # pod membership (None -> pod 0)
    rack: Optional[int] = None      # rack within the pod (None -> rack 0)
    slowdowns: List[Slowdown] = field(default_factory=list)
    _rng: Optional[np.random.Generator] = field(
        default=None, repr=False, compare=False)

    @classmethod
    def from_roofline(cls, name: str = "v5e", *, speed: float = 1.0,
                      jitter: float = 0.0, seed: int = 0,
                      link_latency: float = DEFAULT_LATENCY,
                      pod: Optional[int] = None,
                      rack: Optional[int] = None,
                      flops: Optional[float] = None,
                      hbm_bw: Optional[float] = None,
                      link_bw: Optional[float] = None) -> "NodeProfile":
        """v5e-class node scaled by ``speed``; explicit overrides win
        (benchmarks shrink the constants so toy problems land in a
        regime where compute and comm times are comparable)."""
        return cls(name=name,
                   flops=(flops if flops is not None else PEAK_FLOPS) * speed,
                   hbm_bw=(hbm_bw if hbm_bw is not None else HBM_BW) * speed,
                   link_bw=(link_bw if link_bw is not None else LINK_BW)
                   * speed,
                   link_latency=link_latency, jitter=jitter, seed=seed,
                   pod=pod, rack=rack)

    def add_slowdown(self, start: float, duration: float,
                     factor: float) -> None:
        self.slowdowns.append(Slowdown(start, start + duration, factor))

    def slow_factor(self, now: float) -> float:
        f = 1.0
        for s in self.slowdowns:
            if s.start <= now < s.end:
                f *= s.factor
        return f

    def compute_time(self, flops: float, bytes_accessed: float,
                     now: float) -> float:
        """Roofline step time max(compute, memory) under the node's
        current slowdown, with optional straggler jitter (lognormal,
        mean-one in log space, deterministic per node seed)."""
        base = max(flops / max(self.flops, 1.0),
                   bytes_accessed / max(self.hbm_bw, 1.0))
        base *= self.slow_factor(now)
        if self.jitter > 0.0:
            if self._rng is None:
                # crc32, not hash(): str hashing is salted per process
                # and would break cross-run reproducibility
                self._rng = np.random.default_rng(
                    np.random.SeedSequence(
                        [self.seed, zlib.crc32(self.name.encode())]))
            base *= float(self._rng.lognormal(0.0, self.jitter))
        return base


def make_heterogeneous_profiles(n: int, ratio: float = 1.0, *,
                                jitter: float = 0.0, seed: int = 0,
                                link_latency: float = DEFAULT_LATENCY,
                                flops: Optional[float] = None,
                                hbm_bw: Optional[float] = None,
                                link_bw: Optional[float] = None
                                ) -> List[NodeProfile]:
    """``n`` nodes with speeds geometrically spaced from 1.0 (node 0)
    down to 1/ratio (node n-1) — the paper's "heterogeneous hardware"
    axis.  ratio=1 is a homogeneous cluster."""
    if n <= 0:
        return []
    profiles = []
    for i in range(n):
        expo = i / max(n - 1, 1)
        speed = float(ratio) ** (-expo) if ratio > 0 else 1.0
        profiles.append(NodeProfile.from_roofline(
            name=f"node{i}", speed=speed, jitter=jitter, seed=seed + i,
            link_latency=link_latency, flops=flops, hbm_bw=hbm_bw,
            link_bw=link_bw))
    return profiles


def make_pod_profiles(pod_sizes: List[int], ratio: float = 1.0, *,
                      jitter: float = 0.0, seed: int = 0,
                      link_latency: float = DEFAULT_LATENCY,
                      flops: Optional[float] = None,
                      hbm_bw: Optional[float] = None,
                      link_bw: Optional[float] = None
                      ) -> List[NodeProfile]:
    """Pod-structured cluster: nodes are homogeneous inside a pod and
    pod speeds are geometrically spaced from 1.0 (pod 0) down to
    1/ratio (last pod) — the realistic shape of mixed-generation
    fleets.  Node ``p{i}n{j}`` carries ``pod=i`` so
    :meth:`~repro.cluster.network.Topology.from_profiles` can recover
    the grouping; interleave the returned list before handing it to
    ``run_cluster`` if trainers should span pods."""
    P = len(pod_sizes)
    profiles = []
    for pi, size in enumerate(pod_sizes):
        expo = pi / max(P - 1, 1)
        speed = float(ratio) ** (-expo) if ratio > 0 else 1.0
        for j in range(size):
            profiles.append(NodeProfile.from_roofline(
                name=f"p{pi}n{j}", speed=speed, jitter=jitter,
                seed=seed + 1000 * pi + j, link_latency=link_latency,
                pod=pi, flops=flops, hbm_bw=hbm_bw, link_bw=link_bw))
    return profiles


def make_rack_profiles(shape: List[List[int]], ratio: float = 1.0, *,
                       jitter: float = 0.0, seed: int = 0,
                       link_latency: float = DEFAULT_LATENCY,
                       flops: Optional[float] = None,
                       hbm_bw: Optional[float] = None,
                       link_bw: Optional[float] = None
                       ) -> List[NodeProfile]:
    """Rack/pod-structured cluster for three-level fabrics: ``shape``
    lists, per pod, the node count of each of its racks (``[[2, 2],
    [3]]`` is pod 0 with two 2-node racks and pod 1 with one 3-node
    rack).  Nodes are homogeneous inside a pod and pod speeds are
    geometrically spaced from 1.0 (pod 0) down to 1/``ratio`` (last
    pod), matching :func:`make_pod_profiles`.  Node ``p{i}r{j}n{k}``
    carries ``pod=i, rack=j`` so
    :meth:`~repro.cluster.network.Topology.from_profiles` (with
    ``pod_bw``) can recover the rack -> pod -> cluster tree; interleave
    the returned list before handing it to ``run_cluster`` if trainers
    should span pods."""
    P = len(shape)
    profiles = []
    for pi, racks in enumerate(shape):
        expo = pi / max(P - 1, 1)
        speed = float(ratio) ** (-expo) if ratio > 0 else 1.0
        for ri, size in enumerate(racks):
            for k in range(size):
                profiles.append(NodeProfile.from_roofline(
                    name=f"p{pi}r{ri}n{k}", speed=speed, jitter=jitter,
                    seed=seed + 10000 * pi + 100 * ri + k,
                    link_latency=link_latency, pod=pi, rack=ri,
                    flops=flops, hbm_bw=hbm_bw, link_bw=link_bw))
    return profiles


def interleave_pods(profiles: List[NodeProfile]) -> List[NodeProfile]:
    """Round-robin the profiles across their pods (``pod`` attribute,
    None -> pod 0), so consecutive slices — and therefore the trainers
    ``run_cluster`` carves out of the list — span pods and every outer
    sync crosses the inter-pod bottleneck."""
    groups: dict = {}
    for p in profiles:
        groups.setdefault(p.pod if p.pod is not None else 0, []).append(p)
    ordered = [groups[k] for k in sorted(groups)]
    return [p for tup in itertools.zip_longest(*ordered) for p in tup
            if p is not None]
