"""Batch-growth autoscaling policies: co-scale the worker pool with the
adaptive batch.

AdLoCo's batch-size tests grow the requested global batch roughly
exponentially over training (Theorem 2's ln-N communication profile).
With a fixed pool each trainer's *share* of that batch — its
gradients-per-worker — grows with it, so late rounds pay ever-longer
compute phases while early rounds under-utilize the fleet.  The adadamp
observation is that scaling the worker pool *with* the batch keeps
gradients-per-worker approximately constant, turning batch growth into
fleet growth instead of per-round slowdown.

An :class:`ElasticPolicy` observes each round boundary's decided batch
(the :class:`~repro.core.batching.BatchPlanProtocol` output folded into
``TrainerState.requested_batch``) and scripts joins/leaves through the
existing elastic machinery: the runtime turns its verdict into ordinary
``join``/``leave`` cluster events, so scripted scale-ups pay the real
``point_to_point_time`` state-transfer price (and window-edge re-pricing)
that a scenario-driven join would.  Policies therefore need no knowledge
of the event plumbing — they see four numbers and answer with a signed
worker-count delta.

:class:`BandAutoscale` is the reference policy: a hysteresis band on
gradients-per-worker.  ``requested_batch / pool_size`` above ``hi``
requests a join (if spare capacity exists), below ``lo`` requests a
leave (down to ``min_trainers``); a cooldown suppresses thrashing while
a freshly joined trainer's transfer is still in flight.

Use via :class:`~repro.cluster.runtime.ClusterSpec`::

    spec = ClusterSpec(policy="elastic", profiles=profiles,
                       scenario="autoscale_ramp",
                       autoscale=BandAutoscale(lo=2.0, hi=8.0))
    rep, hist = run_cluster(loss_fn, inits, streams, acfg, spec=spec)

Autoscaling requires ``policy="elastic"`` (the only policy with a
spare-node pool) and records ``autoscale`` applied-events plus fabric
trace instants for every action taken.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

__all__ = ["ElasticPolicy", "BandAutoscale"]


class ElasticPolicy:
    """Protocol for pool-scaling decisions at round boundaries.

    The runtime calls :meth:`decide` once per completed round-boundary
    (after the batch decision folded, before the policy dispatch) with:

    - ``requested_batch``: the largest decided global batch across the
      alive pool (the batch the fleet must serve next round);
    - ``pool_size``: number of alive trainers;
    - ``spare_capacity``: how many *additional* trainers the free
      stream/node pools could currently stand up;
    - ``rounds_since_change``: round boundaries observed since the last
      non-zero verdict (cooldown clock — resets on every action).

    Return a signed worker delta: ``+n`` scripts ``n`` join events,
    ``-n`` scripts ``n`` leave events, ``0`` holds.  The runtime clamps
    joins to spare capacity (exhausted spares record a ``join_skipped``
    applied-event rather than failing) and never scripts the last
    trainer away.
    """

    def decide(self, *, requested_batch: int, pool_size: int,
               spare_capacity: int, rounds_since_change: int) -> int:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


@dataclass(frozen=True)
class BandAutoscale(ElasticPolicy):
    """Keep gradients-per-worker inside ``[lo, hi]`` (adadamp band).

    One worker per verdict: scale-ups pay a real state transfer each,
    so stepping keeps the transfer pipeline (and its re-pricing) honest
    rather than teleporting the pool to the target size.

    - ``lo``/``hi``: gradients-per-worker band.  Above ``hi`` → join,
      below ``lo`` → leave.  ``hi`` should be ≥ ``2 * lo`` or the
      post-action share immediately re-crosses the far edge and the
      pool oscillates.
    - ``min_trainers``/``max_trainers``: hard pool bounds (``None`` =
      no upper bound beyond physical spares).
    - ``cooldown_rounds``: round boundaries to hold after any action —
      lets a joining trainer's transfer land (and the batch decision
      refresh) before re-evaluating.
    """

    lo: float = 2.0
    hi: float = 8.0
    min_trainers: int = 1
    max_trainers: Optional[int] = None
    cooldown_rounds: int = 1

    def __post_init__(self) -> None:
        if not (0.0 < self.lo < self.hi):
            raise ValueError(
                f"need 0 < lo < hi, got lo={self.lo} hi={self.hi}")
        if self.min_trainers < 1:
            raise ValueError("min_trainers must be >= 1")
        if (self.max_trainers is not None
                and self.max_trainers < self.min_trainers):
            raise ValueError("max_trainers must be >= min_trainers")

    def decide(self, *, requested_batch: int, pool_size: int,
               spare_capacity: int, rounds_since_change: int) -> int:
        if rounds_since_change < self.cooldown_rounds:
            return 0
        g = requested_batch / max(1, pool_size)
        if (g > self.hi and spare_capacity > 0
                and (self.max_trainers is None
                     or pool_size < self.max_trainers)):
            return 1
        if g < self.lo and pool_size > self.min_trainers:
            return -1
        return 0

    def describe(self) -> str:
        cap = "inf" if self.max_trainers is None else str(self.max_trainers)
        return (f"BandAutoscale(lo={self.lo}, hi={self.hi}, "
                f"pool=[{self.min_trainers},{cap}], "
                f"cooldown={self.cooldown_rounds})")
