"""Event-driven virtual-cluster runtime for AdLoCo.

Drives the :class:`repro.core.adloco.TrainerRound` primitive over a set
of simulated heterogeneous nodes (``node.py``) connected by a latency +
bandwidth fabric (``network.py``).  Numerics are real — every inner and
outer step runs through the same jitted code as the legacy host loop —
while *time* is simulated: each round's compute is costed by the node
roofline, each outer sync by the ring all-reduce model, and a heap of
timestamped events decides what happens next.

Sync policies
-------------
``sync``     Barrier semantics of ``train_adloco``: a trainer blocks on
             its outer all-reduce before starting the next round.  With
             identical configs (and merging disabled so trainers stay
             independent) this reproduces the legacy loop bit-for-bit —
             only the clock differs.
``async``    ACCO-style overlap: workers keep accumulating inner steps
             while the outer all-reduce is in flight.  The pseudo-
             gradient is computed against the anchor captured at launch
             and applied when the collective arrives; workers rebase
             (``wp <- x_new + (wp - snapshot)``) at the first round
             boundary after arrival, so in-flight progress is kept.
             With a zero-cost network this degenerates to ``sync``.
``elastic``  ``async`` + scenario events: trainers leave (their state is
             folded into the pool via ``mit.do_merge``) and join
             (cloning the most-advanced trainer onto spare nodes and
             streams) mid-run.

Simulation granularity: compute for a round is executed eagerly when the
round is scheduled, so a collective that arrives mid-round takes effect
at the next round boundary; a merge interrupts the in-flight round of
the surviving representative (that round's compute is discarded, as a
real preemption would).

Fabric dynamics: outer syncs are priced through the network model at
launch time — under a :class:`~repro.cluster.network.Topology` that
means reduce-scatter down the fabric levels, a shard ring across the
top bottleneck, and all-gathers back up — and every ``fabric`` scenario
event (congestion window opening or closing) re-prices what is in
flight: collectives (outer syncs *and* adaptive batch-stats
reductions) and join-time point-to-point parameter transfers all have
the fraction already transferred credited and the remainder re-costed
under the new fabric state (model-scale joins spanning a window edge
would otherwise be silently mispriced).

Adaptive batching: when ``acfg.adaptive`` is on, every round ends with
a batch-stats reduction — a real collective on the wire (the two-phase
composition of ``repro.core.batching``: a ``[colsum, count]`` phase-1
vector of one f32 per parameter — the same order as a gradient
all-reduce — plus five scalar moments) priced through the same network
model, counted in ``ClusterReport.num_stats_syncs`` and re-priced at
fabric window edges like any other in-flight collective.  Under the
``sync`` (and ``elastic``) policies the next round's plan depends on
the reduced statistics, so the stats agreement gates the round
boundary.  Under ``async`` the stats cost is *piggybacked* (the Lau et
al. trick): the round's phase-1 vector rides the outer all-reduce as
one fused ``"piggyback"`` collective — priced once at params + stats
bytes — and the batch decision folds when that collective lands
(:meth:`repro.core.adloco.TrainerRound.apply_stats`), giving
one-round-stale plan semantics instead of a serial gradient-sized
reduction per round.  Batch growth then feeds straight back into the
clock: a bigger effective batch means more roofline FLOPs per node per
round, which is how sync/async/elastic trade off under a growing batch
(scenarios ``adaptive_ramp`` / ``congested_adaptive``).

Nonblocking collectives: the runtime *dispatches* every outer sync at
its launch point (:meth:`CollectiveBackend.dispatch_outer`) and waits
for the result only at the arrival event
(:meth:`CollectiveBackend.wait_outer`).  Under the sim backend that is
a semantic no-op (the stack is eager), but on
``JaxProcessBackend`` the jitted collective is enqueued without a
ready-wait, so the next round's inner compute — which the async
schedule runs between launch and arrival — executes while the wire
work is genuinely in flight; the measured span covers the true
dispatch->ready window, making real-clock ``overlap_fraction`` match
the simulated schedule's claim instead of the old 0-by-construction.
"""
from __future__ import annotations

import copy
import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.configs.base import AdLoCoConfig
from repro.core.adloco import History, RoundOutput, TrainerRound
from repro.core.comms import TimedCommsMeter, param_bytes
from repro.core.diloco import merge_params
from repro.core.mit import (TrainerPoolState, check_merge, consolidate,
                            do_merge)
from repro.cluster.backend import CollectiveBackend, SimBackend
from repro.cluster.network import NetworkModel
from repro.cluster.node import NodeProfile, make_heterogeneous_profiles
from repro.cluster.trace import FABRIC_TID, Trace

POLICIES = ("sync", "async", "elastic")


@dataclass
class ClusterEvent:
    """Scripted scenario event.

    kind="slowdown": node ``node`` computes ``factor``x slower for
        ``duration`` simulated seconds.
    kind="leave":    trainer ``tid`` (default: smallest requested batch)
        leaves; its knowledge is merged into the pool via ``do_merge``.
        Scripted leaves model *preemptions*: the leaver's capacity
        slice (nodes and data shards alike) returns to the spare
        pools so the pool can re-grow after churn.  Leaves synthesized
        by an autoscale policy (``autoscaled=True``) model deliberate
        *consolidation* instead: the survivor keeps the unioned shards
        per Algorithm 2 and only the nodes are freed.
    kind="join":     a new trainer joins on spare nodes/streams, cloned
        from the most-advanced trainer.
    kind="fabric":   a congestion window opens on the network for
        ``duration`` simulated seconds (<= 0: permanently): link
        bandwidth is multiplied by ``bw_scale`` and each hop pays
        ``extra_latency``; ``scope`` picks which links of a
        :class:`~repro.cluster.network.Topology` suffer — "all",
        "intra" (leaf domains), "inter" (every internal level),
        "level:<k>" (one level, 0 = leaves) or "domain:<name>" (one
        named domain; the flat model has a single fabric and treats
        every scope as the wire).  In-flight collectives and join
        transfers are re-priced at every window edge.
    """

    time: float
    kind: str
    node: Optional[int] = None
    tid: Optional[int] = None
    factor: float = 2.0
    duration: float = 0.0
    bw_scale: float = 1.0
    extra_latency: float = 0.0
    scope: str = "all"
    # set by maybe_autoscale on the join/leave events it synthesizes;
    # scripted scenario events leave it False
    autoscaled: bool = False


@dataclass
class ClusterReport:
    policy: str
    sim_time: float = 0.0           # simulated seconds to drain the run
    compute_time: float = 0.0       # sum of per-worker busy seconds
    comm_time: float = 0.0          # sum of collective durations
    # measured wire seconds when an execution backend ran the
    # collectives for real (0.0 under the sim backend); deliberately
    # not part of summary() so golden digests stay backend-agnostic
    real_comm_time: float = 0.0
    num_syncs: int = 0
    # batch-stats reductions priced on the wire (adaptive rounds only;
    # their duration is folded into comm_time).  Not part of summary()
    # so pre-adaptive golden digests stay byte-identical; the adaptive
    # golden traces pin it alongside the batch/plan trajectory.
    num_stats_syncs: int = 0
    # adaptive rounds whose batch came from the fitted growth predictor
    # instead of an exact stats reduction (acfg.k_correct > 1); the gap
    # between this and num_stats_syncs is the measured comms cut
    num_predicted_rounds: int = 0
    # scaling actions the ClusterSpec.autoscale policy scripted
    num_autoscale_events: int = 0
    # name of the compiled Scenario the run was driven by (None for raw
    # event lists); extended-summary only, so golden digests stay put
    scenario: Optional[str] = None
    rounds: Dict[int, int] = field(default_factory=dict)   # tid -> rounds
    applied_events: List[dict] = field(default_factory=list)
    # the span/event trace the run recorded into, when one was passed to
    # ``run_cluster(trace=)``; excluded from comparisons so report
    # equality (and the golden digests built on it) stays trace-agnostic
    trace: Optional[Trace] = field(default=None, repr=False, compare=False)

    def summary(self, extended: bool = False) -> dict:
        """Aggregate scalars.  The default call is byte-identical to the
        pre-trace runtime (the golden digests in
        ``tests/goldens/scenarios.json`` pin it); ``extended=True``
        additionally exposes the measured wire time, the stats-reduction
        count and — when the run recorded a trace — the utilization
        ledger aggregate and the overlap fraction (ROADMAP item 1)."""
        s = {"policy": self.policy, "sim_time": self.sim_time,
             "compute_time": self.compute_time,
             "comm_time": self.comm_time, "num_syncs": self.num_syncs,
             "rounds": dict(self.rounds)}
        if extended:
            s["real_comm_time"] = self.real_comm_time
            s["num_stats_syncs"] = self.num_stats_syncs
            s["num_predicted_rounds"] = self.num_predicted_rounds
            s["num_autoscale_events"] = self.num_autoscale_events
            s["scenario"] = self.scenario
            if self.trace is not None:
                util = self.trace.utilization_summary()
                s["utilization"] = util["utilization"]
                s["blocked_frac"] = util["blocked_frac"]
                s["idle_frac"] = util["idle_frac"]
                s["overlap_frac"] = self.trace.overlap_fraction()
                # measured wall-clock overlap (collective in-flight
                # windows vs noted compute); 0.0 under pricing-only
                # backends, > 0 on async runs of a real backend
                s["real_overlap_frac"] = self.trace.overlap_fraction(
                    clock="real")
        return s


@dataclass
class _TrainerRT:
    """Runtime bookkeeping wrapped around a TrainerState."""

    tr: Any
    nodes: List[NodeProfile]
    target: int                     # rounds to run
    round: int = 0                  # completed rounds
    synced: int = 0                 # last round covered by a launched sync
    gen: int = 0                    # bumped on merge/leave to drop stale events
    alive: bool = True
    inflight: bool = False
    worker_params: Optional[List[Any]] = None   # None -> start from tr.params
    pending: Optional[dict] = None  # arrived comm awaiting worker rebase
    last_loss: float = 0.0          # mean loss of the last completed round
    comm_ev: Optional[dict] = None  # in-flight collective (for re-pricing)
    stats_ev: Optional[dict] = None  # in-flight stats reduction (ditto)
    cspan: Optional[Any] = None     # open compute span (tracing only)
    # deferred batch-stats handle awaiting the next outer launch (async
    # piggybacking); a fresher round's handle supersedes an unfused one
    stats_req: Optional[dict] = None


class _Sim:
    def __init__(self, loss_fn: Callable, acfg: AdLoCoConfig, *,
                 policy: str, profiles: List[NodeProfile],
                 backend: CollectiveBackend, eval_fn: Optional[Callable],
                 fixed_batch: Optional[int], verbose: bool,
                 trace: Optional[Trace] = None, autoscale=None):
        self.rnd = TrainerRound(loss_fn, acfg)
        self.trace = trace
        self.acfg = acfg
        self.policy = policy
        self.profiles = profiles
        self.backend = backend
        # ElasticPolicy driving pool size off the batch trajectory; the
        # policy itself is pure — the sim owns the cooldown counter
        self.autoscale = autoscale
        self.autoscale_ticks = 0    # round boundaries since last action
        # async adaptive rounds defer the batch decision and fuse the
        # phase-1 stats vector onto the outer sync (one "piggyback"
        # collective); sync/elastic keep the inline gated stats path
        self.piggyback = (policy == "async" and acfg.adaptive)
        self.eval_fn = eval_fn
        self.fixed_batch = fixed_batch
        self.verbose = verbose
        self.heap: list = []
        self.seq = itertools.count()
        self.hist = History()
        self.report = ClusterReport(policy=policy)
        self.rts: Dict[int, _TrainerRT] = {}
        self.free_nodes: List[NodeProfile] = []
        self.free_streams: List[Any] = []
        self.samples_total = 0
        self.xfers: List[dict] = []     # in-flight join transfers
        self.merged_rounds: set = set()
        self.next_tid = 0
        self.t0 = time.time()
        self.pool: Optional[TrainerPoolState] = None

    # ------------------------------------------------------------ heap
    def push(self, when: float, kind: str, payload: dict) -> None:
        heapq.heappush(self.heap, (when, next(self.seq), kind, payload))

    # ----------------------------------------------------------- alive
    def alive_rts(self) -> List[_TrainerRT]:
        return [rt for rt in self.rts.values() if rt.alive]

    # ------------------------------------------------------ scheduling
    def start_round(self, rt: _TrainerRT, now: float) -> None:
        """Eagerly run the round's compute and schedule its completion."""
        ri = rt.round + 1
        self.maybe_merge(ri, now, caller=rt)
        if not rt.alive or rt.round >= rt.target:
            return
        share = None
        if self.autoscale is not None and self.acfg.adaptive:
            # adadamp: the pool serves the requested batch together, so
            # each trainer executes its gradients-per-worker share (the
            # batch *decision* stays the trainer's full requested batch)
            alive_k = max(1, len(self.alive_rts()))
            share = max(1, -(-int(rt.tr.requested_batch) // alive_k))
        w0 = time.perf_counter()
        out = self.rnd.inner(
            rt.tr, fixed_batch=self.fixed_batch,
            worker_starts=rt.worker_params,
            workers=self.backend.local_workers(
                len(rt.tr.inner_opt_states), tid=rt.tr.tid),
            stats_reduce=self.backend.stats_reducer(),
            defer_stats=self.piggyback, round_i=ri, batch_share=share)
        if out.predicted:
            self.report.num_predicted_rounds += 1
            if self.trace is not None:
                self.trace.instant(rt.tr.tid, "predict", now, round=ri,
                                   batch=int(rt.tr.requested_batch))
        # distributed backends: every process logs the same group loss
        out.mean_loss = self.backend.mean_scalar(out.mean_loss,
                                                 tid=rt.tr.tid)
        # real-clock compute window (mean_scalar forces the round's
        # results): a dispatched collective in flight across this window
        # is measured overlap on the wall clock, not just in the sim
        self.backend.note_real_compute(w0, time.perf_counter() - w0,
                                       tid=rt.tr.tid)
        dts = [node.compute_time(out.flops_per_worker, out.bytes_per_worker,
                                 now)
               for node in rt.nodes[:len(out.worker_params)]]
        self.report.compute_time += sum(dts)
        if self.trace is not None:
            # one span per inner-compute block; the planned end is final
            # unless a merge/leave preempts the round (truncated then)
            rt.cspan = self.trace.begin(
                rt.tr.tid, "compute", now, now + max(dts), round=ri,
                mode=out.mode, samples=out.samples,
                flops=out.flops_per_worker)
        self.push(now + max(dts), "round",
                  {"rt": rt, "out": out, "gen": rt.gen})

    def launch_sync(self, rt: _TrainerRT, now: float,
                    loss: float, mode: str) -> None:
        # callers only launch after a completed round, so worker params
        # are always materialized.  The network model routes the
        # collective: under a Topology the outer all-reduce is priced as
        # reduce-scatter down the fabric levels -> shard ring across the
        # top bottleneck -> all-gathers back up.
        snapshot = list(rt.worker_params)
        payload = param_bytes(rt.tr.params)
        kind, stats_vec, sreq = "outer", None, rt.stats_req
        if sreq is not None:
            # piggyback: the deferred phase-1 stats vector rides this
            # sync as ONE fused collective, priced (and fabric-edge
            # re-priced) once at params + stats bytes
            rt.stats_req = None
            payload += sreq["bytes"]
            kind = "piggyback"
            self.report.num_stats_syncs += 1
            if "phase1" in sreq["req"]:
                stats_vec = sreq["req"]["phase1"]
        dur = self.backend.allreduce_time(payload, rt.nodes, now=now)
        self.pool.comms.record_timed(
            kind, participants=len(rt.tr.inner_opt_states),
            payload_bytes=payload, step=rt.round, duration=dur)
        self.report.comm_time += dur
        self.report.num_syncs += 1
        rt.inflight = True
        rt.synced = rt.round
        ev = {"rt": rt, "gen": rt.gen, "snapshot": snapshot,
              "x_prev": rt.tr.params, "round": rt.round,
              "loss": loss, "mode": mode, "stats_req": sreq,
              # re-pricing state: fraction done as of t_last under the
              # total duration cur_total priced at the last fabric edge
              "payload_bytes": payload, "t_last": now, "frac": 0.0,
              "cur_total": dur, "t_end": now + dur,
              "log": self.pool.comms.log[-1]}
        # nonblocking dispatch: the collective starts NOW (on real
        # backends it is enqueued without a ready-wait and runs under
        # the rounds computed before on_comm_done waits on the handle).
        # A distributed deferred-stats request also hands the backend
        # its phase-2 material so the five-moment reduction can chain
        # onto the same in-flight window (no standalone fold-time sync)
        ev["handle"] = self.backend.dispatch_outer(
            snapshot, stats_vec=stats_vec,
            phase2=(sreq["req"] if sreq is not None
                    and "G_local" in sreq["req"] else None),
            tid=rt.tr.tid, template=rt.tr.params)
        if self.trace is not None:
            ev["span"] = self.trace.begin(
                rt.tr.tid, kind, now, now + dur, round=rt.round,
                mode=mode, payload_bytes=payload)
        rt.comm_ev = ev
        self.push(ev["t_end"], "comm", ev)

    def reprice_inflight(self, now: float) -> None:
        """A fabric window just opened or closed: credit every in-flight
        collective and join transfer with the fraction already
        transferred and re-price the remainder under the new state."""
        for rt in self.rts.values():
            for ev, kind in ((rt.comm_ev, "comm"), (rt.stats_ev, "stats")):
                if (ev is None or not rt.alive
                        or (kind == "comm" and not rt.inflight)
                        or ev["gen"] != rt.gen or ev["t_end"] <= now):
                    continue
                done = ev["frac"]
                if ev["cur_total"] > 0.0:
                    done = min(1.0, done + (now - ev["t_last"])
                               / ev["cur_total"])
                new_total = self.backend.allreduce_time(
                    ev["payload_bytes"], rt.nodes, now=now)
                new_end = now + (1.0 - done) * new_total
                ev.update(frac=done, t_last=now, cur_total=new_total)
                if new_end == ev["t_end"]:
                    continue        # the queued completion is still valid
                delta = new_end - ev["t_end"]
                self.report.comm_time += delta
                self.pool.comms.total_time += delta
                ev["log"]["time_s"] = ev["log"].get("time_s", 0.0) + delta
                if self.trace is not None:
                    self.trace.end(ev.get("span"), new_end)
                    self.trace.instant(
                        rt.tr.tid, "reprice", now, target=kind,
                        frac_done=done, new_total=new_total,
                        delta=delta)
                ev["t_end"] = new_end
                self.push(new_end, kind, ev)
        for ev in self.xfers:
            rt = ev["rt"]
            if (not rt.alive or ev["gen"] != rt.gen
                    or ev["t_end"] <= now):
                continue
            done = ev["frac"]
            if ev["cur_total"] > 0.0:
                done = min(1.0, done + (now - ev["t_last"])
                           / ev["cur_total"])
            new_total = self.backend.point_to_point_time(
                ev["payload_bytes"], ev["src"], ev["dst"], now=now)
            new_end = now + (1.0 - done) * new_total
            ev.update(frac=done, t_last=now, cur_total=new_total)
            if new_end == ev["t_end"]:
                continue
            # the join record appended at launch is a snapshot (its
            # ``xfer_s`` is the launch-time price); a window edge that
            # moves the transfer emits an explicit annotation instead of
            # mutating the already-published event in place, so a
            # consumer that copied ``applied_events`` isn't silently
            # stale.  ``xfer_s`` here is the new effective total —
            # launch to (re-priced) arrival.
            self.report.applied_events.append(
                {"time": now, "kind": "xfer_reprice", "tid": rt.tr.tid,
                 "xfer_s": new_end - ev["log"]["time"]})
            if self.trace is not None:
                self.trace.end(ev.get("span"), new_end)
                self.trace.instant(
                    rt.tr.tid, "reprice", now, target="xfer",
                    frac_done=done, new_total=new_total,
                    delta=new_end - ev["t_end"])
            ev["t_end"] = new_end
            self.push(new_end, "xfer", ev)

    # --------------------------------------------------------- history
    def record(self, rt: _TrainerRT, now: float, round_i: int,
               loss: float, mode: str) -> None:
        hist, pool = self.hist, self.pool
        hist.outer_step.append(round_i)
        hist.loss.append(loss)
        hist.pool_size.append(len(self.alive_rts()))
        hist.requested_batches.append(
            [t.requested_batch for t in pool.trainers])
        hist.comm_events.append(pool.comms.events)
        hist.comm_bytes.append(pool.comms.total_bytes)
        hist.samples.append(self.samples_total)
        hist.modes.append([mode])
        hist.wall.append(time.time() - self.t0)
        hist.sim_time.append(now)
        if self.eval_fn is not None:
            val = float(self.eval_fn(rt.tr.params))
            hist.eval_loss.append(val)
            hist.eval_loss_by_trainer.append({rt.tr.tid: val})
            # what the consolidated model would score *now*: the batch-
            # weighted average of the live pool (mirrors ``consolidate``)
            # — the honest convergence curve for autoscaled pools, where
            # averaging k anchors divides the noise floor the way the
            # paper's merge does
            anchors = [t.tr for t in self.alive_rts()]
            if len(anchors) > 1:
                avg = merge_params(
                    [t.params for t in anchors],
                    [max(t.requested_batch, 1) for t in anchors])
                hist.eval_loss_pool.append(float(self.eval_fn(avg)))
            else:
                hist.eval_loss_pool.append(val)
        if self.verbose:
            print(f"[cluster/{self.policy}] t={now * 1e3:9.3f}ms "
                  f"tid={rt.tr.tid} round={round_i} loss={loss:.4f} "
                  f"k={len(self.alive_rts())}")

    # ---------------------------------------------------------- tracing
    def truncate_spans(self, rt: _TrainerRT, now: float,
                       reason: str) -> None:
        """A gen bump (merge/leave) just preempted this trainer's
        in-flight work: close any open compute/collective spans at the
        preemption time so the trace reflects what actually ran."""
        if self.trace is None:
            return
        open_spans = [rt.cspan,
                      rt.comm_ev.get("span") if rt.comm_ev else None,
                      rt.stats_ev.get("span") if rt.stats_ev else None]
        for span in open_spans:
            if span is not None and span.t1 is not None and span.t1 > now:
                self.trace.end(span, now, **{reason: True})
                self.trace.instant(rt.tr.tid, "preempt", now,
                                   target=span.kind, reason=reason)
        rt.cspan = None

    # -------------------------------------------------------- handlers
    def fold_pending(self, rt: _TrainerRT) -> None:
        """Rebase the workers onto a delayed outer update that arrived
        since the last fold (``wp <- x_new + (wp - snapshot)``) — must
        run before anything is launched from the workers, or the next
        pseudo-gradient diffs against the wrong anchor."""
        if rt.pending is None or rt.worker_params is None:
            return
        x_new, snap = rt.pending["x_new"], rt.pending["snapshot"]
        rt.worker_params = [
            None if wp is None else
            jax.tree.map(lambda xn, w, s: xn + (w - s), x_new, wp, sm)
            for wp, sm in zip(rt.worker_params, snap)]
        rt.pending = None

    def on_round_done(self, now: float, ev: dict) -> None:
        rt: _TrainerRT = ev["rt"]
        if not rt.alive or ev["gen"] != rt.gen:
            return
        out: RoundOutput = ev["out"]
        self.report.sim_time = max(self.report.sim_time, now)
        rt.cspan = None                   # compute span closed on time
        rt.round += 1
        self.report.rounds[rt.tr.tid] = rt.round
        self.samples_total += out.samples
        rt.worker_params = out.worker_params
        rt.last_loss = out.mean_loss
        self.fold_pending(rt)             # delayed outer arrived mid-round

        if out.stats_bytes > 0.0:
            if self.piggyback and out.stats_request is not None:
                # async adaptive: no standalone stats collective — stash
                # the stale stats handle; the next outer launch fuses
                # its phase-1 vector onto the sync and the decision
                # folds when that collective lands (one-round-stale plan
                # semantics).  A fresher handle supersedes an unfused
                # predecessor so the decision always uses the newest
                # gradients that reached a launch point.
                rt.stats_req = {"req": out.stats_request,
                                "bytes": out.stats_bytes,
                                "round": rt.round}
                self.after_stats(rt, now, out.mean_loss, out.mode)
                return
            # sync/elastic: the batch-stats reduction is a collective
            # on the wire — the next round's plan depends on its result,
            # so it gates the round boundary
            self.launch_stats(rt, now, out.mean_loss, out.mode,
                              out.stats_bytes)
            return
        self.after_stats(rt, now, out.mean_loss, out.mode)

    def launch_stats(self, rt: _TrainerRT, now: float, loss: float,
                     mode: str, payload: float) -> None:
        dur = self.backend.allreduce_time(payload, rt.nodes, now=now)
        self.pool.comms.record_timed(
            "stats", participants=len(rt.tr.inner_opt_states),
            payload_bytes=payload, step=rt.round, duration=dur)
        self.report.comm_time += dur
        self.report.num_stats_syncs += 1
        ev = {"rt": rt, "gen": rt.gen, "loss": loss, "mode": mode,
              "payload_bytes": payload, "t_last": now, "frac": 0.0,
              "cur_total": dur, "t_end": now + dur,
              "log": self.pool.comms.log[-1]}
        if self.trace is not None:
            ev["span"] = self.trace.begin(
                rt.tr.tid, "stats", now, now + dur, round=rt.round,
                payload_bytes=payload)
        rt.stats_ev = ev
        self.push(ev["t_end"], "stats", ev)

    def on_stats_done(self, now: float, ev: dict) -> None:
        rt: _TrainerRT = ev["rt"]
        if not rt.alive or ev["gen"] != rt.gen:
            return
        if ev is not rt.stats_ev or now != ev["t_end"]:
            return                   # superseded by a fabric re-pricing
        self.report.sim_time = max(self.report.sim_time, now)
        rt.stats_ev = None
        measured = self.backend.pop_stats_measured()
        if measured is not None:
            self.report.real_comm_time += measured
            self.pool.comms.add_real_time(ev["log"], measured)
        self.after_stats(rt, now, ev["loss"], ev["mode"])

    def after_stats(self, rt: _TrainerRT, now: float, loss: float,
                    mode: str) -> None:
        """Round boundary proper (after any stats agreement arrived)."""
        # a delayed outer can land while the stats reduction is in
        # flight (async/elastic): fold it before launching, exactly as
        # the un-gated round boundary would have
        self.fold_pending(rt)
        self.maybe_autoscale(now)
        if self.policy == "sync":
            # barrier: wait for the collective before the next round
            self.launch_sync(rt, now, loss, mode)
            return

        # async / elastic: overlap — launch if the wire is free, keep
        # computing either way
        if not rt.inflight:
            self.launch_sync(rt, now, loss, mode)
        if rt.round < rt.target:
            self.start_round(rt, now)

    def on_comm_done(self, now: float, ev: dict) -> None:
        rt: _TrainerRT = ev["rt"]
        if not rt.alive or ev["gen"] != rt.gen:
            return
        if ev is not rt.comm_ev or now != ev["t_end"]:
            return                   # superseded by a fabric re-pricing
        self.report.sim_time = max(self.report.sim_time, now)
        rt.inflight = False
        rt.comm_ev = None
        stacked, stats_tot = self.backend.wait_outer(ev["handle"])
        # measured staleness in rounds: rounds already folded since the
        # snapshot, plus the in-flight round that will rebase onto this
        # update at its boundary (async steady state: 1; sync: 0)
        delay = float(rt.round - ev["round"])
        if self.policy != "sync" and rt.round < rt.target:
            delay += 1.0
        self.rnd.outer(rt.tr, ev["snapshot"], x_prev=ev["x_prev"],
                       reduce=lambda _wp: stacked, delay=delay)
        measured = self.backend.pop_measured()
        if measured is not None:
            self.report.real_comm_time += measured
            self.pool.comms.add_real_time(ev["log"], measured)
        sreq = ev.get("stats_req")
        if sreq is not None:
            # fold the piggybacked batch decision: local-estimator
            # requests carry finished statistics; distributed requests
            # finish from the phase-2 moments total the backend chained
            # onto the outer window at dispatch time (pop_phase2_total),
            # falling back to the small standalone reducer for backends
            # that didn't chain it
            self.rnd.apply_stats(rt.tr, sreq["req"],
                                 phase1_total=stats_tot,
                                 phase2_total=(
                                     self.backend.pop_phase2_total()),
                                 sum_reduce=self.backend.stats_reducer(),
                                 round_i=sreq.get("round"))
            ms = self.backend.pop_stats_measured()
            if ms is not None:
                self.report.real_comm_time += ms
                self.pool.comms.add_real_time(ev["log"], ms)
        self.record(rt, now, ev["round"], ev["loss"], ev["mode"])

        if self.policy == "sync":
            rt.worker_params = None            # workers restart from x_new
            if rt.round < rt.target:
                self.start_round(rt, now)
            return

        rt.pending = {"x_new": rt.tr.params, "snapshot": ev["snapshot"]}
        if rt.round >= rt.target:
            # workers idle: fold the rebase now and flush any unsynced
            # progress so the final anchor includes every round
            self.fold_pending(rt)
            if rt.synced < rt.round:
                self.launch_sync(rt, now, rt.last_loss, "flush")

    # ------------------------------------------------------- autoscale
    def maybe_autoscale(self, now: float) -> None:
        """Let the ``ClusterSpec.autoscale`` policy observe the batch
        trajectory at a round boundary and script joins/leaves through
        the same machinery scenario events use (joins pay real
        point-to-point transfer prices, re-priced at fabric edges)."""
        if self.autoscale is None:
            return
        alive = self.alive_rts()
        if not alive:
            return
        M = self.acfg.nodes_per_gpu
        self.autoscale_ticks += 1
        b = max(int(rt.tr.requested_batch) for rt in alive)
        k = len(alive)
        spare = min(len(self.free_streams) // M, len(self.free_nodes) // M)
        action = int(self.autoscale.decide(
            requested_batch=b, pool_size=k, spare_capacity=spare,
            rounds_since_change=self.autoscale_ticks))
        if action == 0:
            return
        self.autoscale_ticks = 0
        kind = "join" if action > 0 else "leave"
        for _ in range(abs(action)):
            self.push(now, "scenario",
                      {"ev": ClusterEvent(time=now, kind=kind,
                                          autoscaled=True)})
        self.report.num_autoscale_events += 1
        self.report.applied_events.append(
            {"time": now, "kind": "autoscale", "action": action,
             "pool": k, "requested_batch": b,
             "gradients_per_worker": b / k})
        if self.trace is not None:
            self.trace.instant(FABRIC_TID, "autoscale", now, action=action,
                               pool=k, requested_batch=b)

    # ---------------------------------------------------------- merges
    def maybe_merge(self, round_i: int, now: float,
                    caller: Optional[_TrainerRT]) -> None:
        acfg = self.acfg
        alive = self.alive_rts()
        if not (acfg.enable_merge and len(alive) > 1
                and round_i % acfg.merge_frequency == 0
                and round_i not in self.merged_rounds):
            return
        # Merges are tagged with their originating round and fire ON
        # TIME: trainers whose round counter drifted more than
        # ``merge_drift_window`` behind the caller's are skipped (their
        # params are rounds stale — folding them in would drag the
        # survivor backwards), instead of the old behavior of stalling
        # the whole merge until the slowest trainer caught up and then
        # merging arbitrarily drifted states.  The window is measured
        # against ``round_i - 1`` (the round the caller just folded);
        # same-speed peers whose fold event shares this timestamp but
        # has not popped yet read one behind, so the default window of
        # 1 is the tightest setting that keeps lockstep peers eligible.
        self.merged_rounds.add(round_i)
        eligible = [rt for rt in alive
                    if (round_i - 1) - rt.round <= acfg.merge_drift_window]
        skipped = sorted(rt.tr.tid for rt in alive if rt not in eligible)
        if len(eligible) <= 1:
            self.report.applied_events.append(
                {"time": now, "kind": "merge_skipped", "round": round_i,
                 "skipped": skipped})
            return
        elig_tids = {rt.tr.tid for rt in eligible}
        elig_ids = [i for i, t in enumerate(self.pool.trainers)
                    if t.tid in elig_tids]
        sub = check_merge(
            [self.pool.trainers[i].requested_batch for i in elig_ids],
            acfg.merge_w + 1)
        ids = [elig_ids[j] for j in sub]
        if len(ids) <= 1:
            return
        involved = [self.pool.trainers[i] for i in ids]
        # on multi-group backends the weighted average executes as a
        # real cross-group collective (merge_reducer); its wall-clock
        # cost lands in real_comm_time like any other collective, while
        # the sim clock stays the analytic price
        self.pool = do_merge(self.pool, ids, step=round_i,
                             reduce=self.backend.merge_reducer())
        ms = self.backend.pop_merge_measured()
        if ms is not None:
            self.report.real_comm_time += ms
            self.pool.comms.add_real_time(self.pool.comms.log[-1], ms)
        # survivor detection is rank-indexable (tids are stable and
        # unique), not keyed on in-process object identity
        surviving = {t.tid for t in self.pool.trainers}
        for t in involved:
            rt = self.rts[t.tid]
            self.truncate_spans(rt, now, "merged")
            if t.tid in surviving:
                # representative: a merge preempts its in-flight round
                # and supersedes any in-flight sync or deferred stats
                rt.gen += 1
                rt.inflight = False
                rt.pending = None
                rt.worker_params = None
                rt.stats_req = None
                if rt is not caller and rt.round < rt.target:
                    self.start_round(rt, now)
            else:
                rt.alive = False
                self.free_nodes.extend(rt.nodes)
                if self.trace is not None:
                    self.trace.trainer_dead(t.tid, now)
        merged_away = [t.tid for t in involved if t.tid not in surviving]
        if self.trace is not None:
            for tid in merged_away:
                self.trace.instant(tid, "merge", now, round=round_i,
                                   skipped=skipped)
        self.report.applied_events.append(
            {"time": now, "kind": "merge", "round": round_i,
             "merged": merged_away, "skipped": skipped})

    # -------------------------------------------------------- scenario
    def on_scenario(self, now: float, ev: ClusterEvent) -> None:
        if ev.kind == "slowdown":
            idx = ev.node if ev.node is not None else 0
            if 0 <= idx < len(self.profiles):
                self.profiles[idx].add_slowdown(now, ev.duration, ev.factor)
                self.report.applied_events.append(
                    {"time": now, "kind": "slowdown", "node": idx,
                     "factor": ev.factor, "duration": ev.duration})
                if self.trace is not None:
                    self.trace.instant(FABRIC_TID, "slowdown", now,
                                       node=idx, factor=ev.factor,
                                       duration=ev.duration)
            return
        if ev.kind == "leave":
            self.do_leave(now, ev.tid, reclaim=not ev.autoscaled)
            return
        if ev.kind == "join":
            self.do_join(now)
            return
        if ev.kind == "fabric":
            self.backend.add_fabric_window(
                now, ev.duration, bw_scale=ev.bw_scale,
                extra_latency=ev.extra_latency, scope=ev.scope)
            self.report.applied_events.append(
                {"time": now, "kind": "fabric", "scope": ev.scope,
                 "bw_scale": ev.bw_scale, "extra_latency": ev.extra_latency,
                 "duration": ev.duration})
            if self.trace is not None:
                # permanent windows (duration <= 0) stay open until
                # Trace.finalize clamps them to the end of the run
                self.trace.begin(
                    FABRIC_TID, "fabric", now,
                    now + ev.duration if ev.duration > 0 else None,
                    scope=ev.scope, bw_scale=ev.bw_scale,
                    extra_latency=ev.extra_latency)
            self.reprice_inflight(now)
            if ev.duration > 0:      # re-price again when the window closes
                self.push(now + ev.duration, "reprice", {})
            return
        raise ValueError(f"unknown scenario event kind: {ev.kind!r}")

    def do_leave(self, now: float, tid: Optional[int], *,
                 reclaim: bool = True) -> None:
        alive = self.alive_rts()
        if len(alive) <= 1:
            return                               # last trainer can't leave
        if tid is None:
            leaver = min(alive, key=lambda rt: rt.tr.requested_batch).tr
        else:
            if tid not in self.rts or not self.rts[tid].alive:
                return
            leaver = self.rts[tid].tr
        # a leaving trainer stops requesting work, so it can never be the
        # merge representative and its merge weight drops to the floor
        leaver.requested_batch = 0
        others = [t for t in self.pool.trainers if t is not leaver]
        best = max(others, key=lambda t: t.requested_batch)
        ids = [self.pool.trainers.index(leaver),
               self.pool.trainers.index(best)]
        keep = len(best.streams)
        self.pool = do_merge(self.pool, ids, step=self.rts[leaver.tid].round)
        lrt = self.rts[leaver.tid]
        self.truncate_spans(lrt, now, "left")
        lrt.alive = False
        # On a preemption (scripted leave) both halves of the leaver's
        # capacity return to the spare pools: its nodes, and the data
        # shards do_merge just unioned onto the survivor (the
        # survivor's own M workers never read past streams[M-1], so
        # the union was pure bookkeeping) are reclaimed as spares —
        # appended at the BACK, so joins keep drawing the
        # originally-provisioned spares first.  Without the
        # reclamation a preemption storm permanently exhausted join
        # capacity: streams were hoarded by survivors while nodes sat
        # free, and the autoscaler's spare_capacity stuck at zero.
        # Autoscaler-decided shrinks (reclaim=False) keep the union on
        # the survivor: a policy shrink consolidates data coverage
        # onto fewer trainers, it does not evict capacity.
        if reclaim:
            reclaimed = best.streams[keep:]
            del best.streams[keep:]
            self.free_streams.extend(reclaimed)
        self.free_nodes.extend(lrt.nodes)
        brt = self.rts[best.tid]
        self.truncate_spans(brt, now, "absorbed_leave")
        brt.gen += 1
        brt.inflight = False
        brt.pending = None
        brt.worker_params = None
        brt.stats_req = None
        if brt.round < brt.target:
            self.start_round(brt, now)
        if self.trace is not None:
            self.trace.trainer_dead(leaver.tid, now)
            self.trace.instant(leaver.tid, "leave", now, into=best.tid)
        self.report.applied_events.append(
            {"time": now, "kind": "leave", "tid": leaver.tid,
             "into": best.tid})

    def do_join(self, now: float) -> None:
        M = self.acfg.nodes_per_gpu
        alive = self.alive_rts()
        if not alive:
            return                               # nothing to clone from
        remaining = max(rt.target - rt.round for rt in alive)
        if remaining <= 0:
            return                               # run is over anyway
        if len(self.free_streams) < M or len(self.free_nodes) < M:
            # spare pool exhausted: record the skip (like drifted-merge
            # skips) instead of silently dropping the join — sweeps that
            # under-provision spares can now see it in applied_events
            self.report.applied_events.append(
                {"time": now, "kind": "join_skipped",
                 "free_streams": len(self.free_streams),
                 "free_nodes": len(self.free_nodes), "needed": M})
            if self.trace is not None:
                self.trace.instant(FABRIC_TID, "join", now, skipped=True,
                                   free_streams=len(self.free_streams),
                                   free_nodes=len(self.free_nodes))
            return
        src = max(alive, key=lambda rt: rt.tr.requested_batch)
        streams = [self.free_streams.pop(0) for _ in range(M)]
        nodes = [self.free_nodes.pop(0) for _ in range(M)]
        tr = self.rnd.new_trainer(self.next_tid, src.tr.params, streams)
        if self.autoscale is not None:
            # an autoscaled joiner inherits the source's batch
            # trajectory: the pool co-serves the requested batch, so a
            # newcomer restarting from the initial batch would skew the
            # gradients-per-worker share it was recruited to absorb
            tr.requested_batch = src.tr.requested_batch
        self.next_tid += 1
        self.pool.trainers.append(tr)
        rt = _TrainerRT(tr=tr, nodes=nodes, target=remaining)
        self.rts[tr.tid] = rt
        # parameter shipping to the newcomer costs one point-to-point
        # xfer, tracked in flight so fabric window edges re-price it
        # (fraction done credited) exactly like a collective
        payload = param_bytes(tr.params)
        xfer = self.backend.point_to_point_time(
            payload, src.nodes[0], nodes[0], now=now)
        log = {"time": now, "kind": "join", "tid": tr.tid,
               "cloned_from": src.tr.tid, "xfer_s": xfer}
        self.report.applied_events.append(log)
        ev = {"rt": rt, "gen": rt.gen, "payload_bytes": payload,
              "src": src.nodes[0], "dst": nodes[0],
              "t_last": now, "frac": 0.0, "cur_total": xfer,
              "t_end": now + xfer, "log": log}
        if self.trace is not None:
            # the joiner is alive (and comm-blocked) from the moment its
            # parameters start shipping
            self.trace.trainer_alive(tr.tid, now)
            self.trace.instant(tr.tid, "join", now,
                               cloned_from=src.tr.tid)
            ev["span"] = self.trace.begin(
                tr.tid, "xfer", now, now + xfer, payload_bytes=payload,
                src=src.nodes[0].name, dst=nodes[0].name,
                cloned_from=src.tr.tid)
        self.xfers.append(ev)
        self.push(ev["t_end"], "xfer", ev)

    def on_xfer_done(self, now: float, ev: dict) -> None:
        rt: _TrainerRT = ev["rt"]
        if ev["t_end"] != now:
            return                   # superseded by a fabric re-pricing
        self.xfers.remove(ev)
        if not rt.alive or ev["gen"] != rt.gen:
            return
        self.start_round(rt, now)


@dataclass(frozen=True)
class ClusterSpec:
    """Everything about a cluster run that is not the model or the data.

    ``run_cluster`` grew one keyword per feature until the autoscaler
    would have been the fourteenth; the spec is the one record that
    carries them all.  Legacy keywords still work (each is a thin alias
    that builds this spec), but a spec cannot be combined with them —
    mixing the two spellings raises.

    ``autoscale`` is an :class:`~repro.cluster.autoscale.ElasticPolicy`
    observing the adaptive batch trajectory at every round boundary and
    scripting joins/leaves through the elastic machinery; it requires
    ``policy="elastic"``.
    """

    policy: str = "sync"
    profiles: Optional[List[NodeProfile]] = None
    network: Optional[NetworkModel] = None
    backend: Optional[CollectiveBackend] = None
    num_outer_steps: Optional[int] = None
    eval_fn: Optional[Callable] = None
    fixed_batch: Optional[int] = None
    scenario: Any = ()
    trace: Optional[Trace] = None
    autoscale: Optional[Any] = None
    verbose: bool = False


_UNSET = object()    # distinguishes "kwarg not passed" from its default


def run_cluster(loss_fn: Callable, init_params_list: List[Any],
                streams: List[Any], acfg: AdLoCoConfig, *,
                spec: Optional[ClusterSpec] = None,
                policy=_UNSET, profiles=_UNSET, network=_UNSET,
                backend=_UNSET, num_outer_steps=_UNSET, eval_fn=_UNSET,
                fixed_batch=_UNSET, scenario=_UNSET, trace=_UNSET,
                autoscale=_UNSET, verbose=_UNSET):
    """Train AdLoCo on a simulated heterogeneous cluster.

    The run is configured by a :class:`ClusterSpec` — ``spec=`` is the
    canonical spelling; every individual keyword below is a deprecated
    alias that builds the same spec (bit-identical behavior, pinned by
    the golden-digest suite) and cannot be mixed with ``spec=``.

    ``streams`` beyond the initial k*M shards form the spare pool handed
    to trainers that join mid-run (elastic scenarios); ``profiles``
    beyond k*M likewise.  ``network`` is a flat :class:`NetworkModel`
    (default) or an n-level :class:`~repro.cluster.network.Topology`
    (tree of fabric domains) — the choice changes the simulated clock,
    never the numerics.  ``backend`` picks *how* collectives execute
    (see ``repro.cluster.backend``): the default
    :class:`~repro.cluster.backend.SimBackend` wraps ``network`` and
    prices them analytically; a
    :class:`~repro.cluster.backend.JaxProcessBackend` (one process per
    worker, launched via ``repro.cluster.launch_mp``) runs them as real
    ``jax.lax`` collectives and carries its own pricing network —
    passing both ``backend=`` and ``network=`` is an error.
    ``scenario`` is a sequence of :class:`ClusterEvent`\\ s, a compiled
    :class:`~repro.cluster.scenarios.Scenario`, or the name of a
    registered scenario (see ``repro.cluster.scenarios``); a named
    scenario's name is threaded into ``summary(extended=True)``.
    ``autoscale`` hands the elastic pool to an
    :class:`~repro.cluster.autoscale.ElasticPolicy` (see the
    "Autoscaling" section of ``repro.cluster``'s docstring).
    ``trace`` is an optional :class:`~repro.cluster.trace.Trace` (or
    ``True`` to allocate one) the event loop records typed spans into —
    inner-compute blocks, outer collectives, stats reductions, join
    transfers, fabric windows — plus instant annotations for
    re-pricings, merges, joins, leaves, slowdowns, autoscale actions
    and predicted batch decisions; real backends add measured
    wall-clock spans.  Recording never changes the schedule, and with
    the default ``None`` the instrumentation is a no-op.  The populated
    trace is also attached to ``ClusterReport.trace`` so
    ``report.summary(extended=True)`` can expose the utilization ledger
    and the overlap fraction.
    Returns (TrainerPoolState, History, ClusterReport) — the History
    carries ``sim_time`` so convergence can be plotted against the
    simulated clock.
    """
    legacy = {name: val for name, val in (
        ("policy", policy), ("profiles", profiles), ("network", network),
        ("backend", backend), ("num_outer_steps", num_outer_steps),
        ("eval_fn", eval_fn), ("fixed_batch", fixed_batch),
        ("scenario", scenario), ("trace", trace), ("autoscale", autoscale),
        ("verbose", verbose)) if val is not _UNSET}
    if spec is not None:
        if legacy:
            raise ValueError(
                f"configure the run through spec= OR the legacy keyword "
                f"aliases, not both (got spec= plus {sorted(legacy)})")
    else:
        spec = ClusterSpec(**legacy)

    policy, scenario = spec.policy, spec.scenario
    profiles, network, backend = spec.profiles, spec.network, spec.backend
    eval_fn, fixed_batch, trace = spec.eval_fn, spec.fixed_batch, spec.trace
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    if isinstance(scenario, str):
        from repro.cluster.scenarios import build_scenario
        scenario = build_scenario(scenario)
    scenario_name = getattr(scenario, "name", None)
    if spec.autoscale is not None and policy != "elastic":
        raise ValueError(
            f"autoscale= scripts joins/leaves and needs the elastic "
            f"pool; run with policy='elastic', not {policy!r}")
    k, M = len(init_params_list), acfg.nodes_per_gpu
    T = spec.num_outer_steps or acfg.num_outer_steps
    if profiles is None:
        profiles = make_heterogeneous_profiles(k * M)
    if len(profiles) < k * M:
        raise ValueError(f"need >= {k * M} node profiles, got "
                         f"{len(profiles)}")
    if backend is not None and network is not None:
        raise ValueError("pass the pricing network inside the backend, "
                         "not both backend= and network=")
    if backend is None:
        backend = SimBackend(network)
    # the sim mutates node and fabric state (jitter RNG draws, scenario
    # slowdowns, congestion windows): work on copies so caller-owned
    # profiles/networks stay reusable and repeated runs are independent
    # and reproducible (``for_run`` copies the backend's pricing state)
    profiles = [copy.deepcopy(p) for p in profiles]
    backend = backend.for_run()
    backend.bind(profiles)
    backend.validate(acfg, policy=policy, k=k, M=M, scenario=scenario,
                     autoscale=spec.autoscale)
    if trace is True:
        trace = Trace()
    if trace is not None:
        backend.attach_trace(trace)

    sim = _Sim(loss_fn, acfg, policy=policy, profiles=list(profiles),
               backend=backend, eval_fn=eval_fn, fixed_batch=fixed_batch,
               verbose=spec.verbose, trace=trace, autoscale=spec.autoscale)
    sim.report.scenario = scenario_name
    sim.pool = sim.rnd.init_pool(init_params_list, streams[:k * M])
    sim.pool.comms = TimedCommsMeter()
    if fixed_batch is not None and not acfg.adaptive:
        for t in sim.pool.trainers:
            t.requested_batch = fixed_batch
    sim.free_streams = list(streams[k * M:])
    sim.free_nodes = list(profiles[k * M:])
    sim.next_tid = k
    for i, t in enumerate(sim.pool.trainers):
        sim.rts[t.tid] = _TrainerRT(
            tr=t, nodes=list(profiles[i * M:(i + 1) * M]), target=T)
        if trace is not None:
            trace.trainer_alive(t.tid, 0.0)

    for ev in sorted(scenario, key=lambda e: e.time):
        sim.push(ev.time, "scenario", {"ev": ev})
    # windows pre-installed on the caller's fabric schedules must also
    # re-price in-flight collectives at their edges (scenario-delivered
    # windows handle this when the fabric event is applied)
    for t in backend.fabric_change_points():
        sim.push(t, "reprice", {})
    for rt in sim.rts.values():
        sim.start_round(rt, 0.0)

    while sim.heap:
        when, _, kind, payload = heapq.heappop(sim.heap)
        if kind == "round":
            sim.on_round_done(when, payload)
        elif kind == "comm":
            sim.on_comm_done(when, payload)
        elif kind == "stats":        # batch-stats reduction arrived
            sim.on_stats_done(when, payload)
        elif kind == "xfer":         # join transfer finished shipping
            sim.on_xfer_done(when, payload)
        elif kind == "reprice":      # a fabric window closed
            sim.reprice_inflight(when)
        else:
            sim.on_scenario(when, payload["ev"])

    if trace is not None:
        trace.finalize(sim.report.sim_time)
        sim.report.trace = trace
    # on multi-group backends the final consolidate is a real global
    # collective even for a pool of one — it doubles as the broadcast
    # that re-replicates the surviving model on every rank after merges
    pool = consolidate(sim.pool, step=T, reduce=backend.merge_reducer())
    ms = backend.pop_merge_measured()
    if ms is not None:
        sim.report.real_comm_time += ms
        if pool.comms.log and pool.comms.log[-1]["kind"] == "consolidate":
            pool.comms.add_real_time(pool.comms.log[-1], ms)
    return pool, sim.hist, sim.report
