"""Pluggable execution backends for the cluster runtime.

``Topology`` describes *where* a hierarchical all-reduce runs — which
fabric domains a collective crosses and what each level's paths cost.
A :class:`CollectiveBackend` supplies *how*: the same runtime event loop
drives either

:class:`SimBackend`
    The default.  Collectives are *priced* analytically (delegating to
    the wrapped :class:`~repro.cluster.network.NetworkModel` /
    :class:`~repro.cluster.network.Topology`) and *executed* locally —
    the outer reduction is the in-process ``jnp.stack`` the runtime has
    always done.  Behavior is bit-identical to the pre-backend runtime;
    the golden-trace suite pins that.
:class:`JaxProcessBackend`
    One OS process per worker via ``jax.distributed.initialize`` (see
    ``repro.cluster.launch_mp``): every process runs the *same*
    deterministic event loop, computes only its own worker's inner
    steps, and the outer reduction executes as a real ``jax.lax``
    collective across processes.  The simulated clock still comes from
    the analytic network model (so reports stay comparable), while the
    wall-clock actually spent inside each collective is recorded
    separately (``ClusterReport.real_comm_time`` and per-event
    ``real_s``).  When the pricing network is a
    ``Topology``, the participant-pruned :class:`~repro.cluster.network.
    FabricDomain` tree is mapped onto nested mesh axes, so the reduction
    lowers to grouped all-reduces per fabric level — intra-leaf process
    groups first, then the cross-domain groups, exactly where the tree
    says the hierarchical schedule runs (unbalanced participant trees
    fall back to one flat group).

Lockstep contract (distributed backends): every process must pop the
same events in the same order, so collectives launch identically
everywhere.  That holds because pricing is pure float arithmetic on
state every process replicates (profiles, network, scenario).
Adaptive batching joins the contract through the batch-stats all-reduce
(:meth:`CollectiveBackend.stats_reducer`): each rank contributes its
worker's gradient rows to the exact two-phase composition of
``repro.core.batching.distributed_stats`` — executed here as real
``lax.pmean``\\ s over the fabric mesh — so every rank derives the
identical requested batch and compiled shapes from the identical
reduced statistics (``repro.core.adloco.BatchPlanProtocol``).
Multi-trainer pools (MIT, paper §4.1) map onto *disjoint process
groups*: with ``k > 1`` trainers of ``M`` workers each, the mesh gains
a leading ``"t"`` axis indexing the groups and the fabric axes only
ever appear in grouped reductions, so each trainer's outer sync is a
``lax.pmean`` over its own workers and nothing else.  ``do_merge`` /
``consolidate`` become real *cross-group* collectives through
:meth:`CollectiveBackend.merge_reducer`: members contribute their
trainer's weighted replica, a global ``psum`` over every axis folds
numerator and total weight, and the result lands replicated on every
rank (which is also what repairs non-member replicas after pool
contraction).  :meth:`JaxProcessBackend.validate` still rejects what
would let processes diverge: the rank-local per-sample probe estimator
(its statistics live on one rank's params; use the composable
``stats_estimator="microbatch"``), elastic joins/leaves and
autoscaling (the process set cannot grow or shrink mid-run), and
adaptive batching over ``k > 1`` (the stats reductions are global,
not per-group).
"""
from __future__ import annotations

import copy
import time
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.cluster.network import NetworkModel
from repro.cluster.node import NodeProfile


class CollectiveBackend:
    """Protocol: pricing (simulated clock) + execution (numerics).

    Pricing methods mirror the network-model interface so the runtime
    can stay network-agnostic; execution methods carry the actual
    parameter movement.  ``outer_reduce`` must return a pytree whose
    leaves have a leading *worker* axis ready for
    ``repro.core.diloco.make_outer_step``'s mean — either the full
    (M, ...) stack (sim) or an already-reduced (1, ...) mean (real
    collectives).
    """

    name = "abstract"

    # ------------------------------------------------------------ setup
    def for_run(self) -> "CollectiveBackend":
        """Per-run copy of the mutable pricing state (the runtime opens
        fabric windows and the sim draws jitter); process-level handles
        (meshes, distributed clients) are shared, not copied."""
        raise NotImplementedError

    def bind(self, profiles: Sequence[NodeProfile]) -> None:
        """Associate the run's node profiles (index i = worker i)."""

    def validate(self, acfg, *, policy: str, k: int, M: int,
                 scenario: Sequence[Any] = (),
                 autoscale: Optional[Any] = None) -> None:
        """Reject configurations this backend cannot execute."""

    def attach_trace(self, trace) -> None:
        """Record *wall-clock* spans for executed collectives into
        ``trace`` (see ``repro.cluster.trace``).  Pricing-only backends
        ignore it — the runtime records the simulated spans itself."""

    # ---------------------------------------------------------- pricing
    def allreduce_time(self, payload_bytes: float,
                       nodes: Sequence[NodeProfile], *,
                       now: float = 0.0) -> float:
        raise NotImplementedError

    def point_to_point_time(self, payload_bytes: float, src: NodeProfile,
                            dst: NodeProfile, *, now: float = 0.0) -> float:
        raise NotImplementedError

    def add_fabric_window(self, start: float,
                          duration: Optional[float] = None, *,
                          bw_scale: float = 1.0, extra_latency: float = 0.0,
                          scope: str = "all") -> None:
        raise NotImplementedError

    def fabric_change_points(self) -> List[float]:
        return []

    # -------------------------------------------------------- execution
    def local_workers(self, M: int, *,
                      tid: Optional[int] = None) -> Optional[List[int]]:
        """Worker indices this process computes for trainer ``tid``;
        None means all (the single-process sim).  Multi-group backends
        return ``[]`` on ranks outside the trainer's group — those
        ranks still participate in its collectives (lockstep), they
        just contribute nothing."""
        return None

    def outer_reduce(self, worker_params: List[Any]) -> Any:
        """List of per-worker pytrees (None for workers that live on
        other processes) -> pytree with a leading worker axis."""
        raise NotImplementedError

    # ------------------------------------------- dispatch/handle split
    #
    # The nonblocking contract: ``dispatch_outer`` *starts* the outer
    # collective (optionally fused with the phase-1 batch-stats vector —
    # Lau-style piggybacking) and returns an opaque handle immediately;
    # ``wait_outer`` blocks until the wire work is done, records the
    # *true in-flight window* (dispatch -> ready) as the measured
    # wall-clock span, and returns the results.  The runtime dispatches
    # at the sim's launch point and waits at the rebase/fold point, so
    # the next round's inner steps run while the collective is in
    # flight.  Every rank reaches both calls in the same (lockstep)
    # event order, so dispatch order is identical everywhere.  Handles
    # are per-trainer: with k > 1 groups (or async stats) several can
    # be in flight together, dispatched in lockstep order.  A handle
    # abandoned by preemption (a merge superseding an in-flight sync)
    # is safe to drop on real backends too: the collective was already
    # enqueued on *every* rank at dispatch, so nobody blocks on a
    # missing partner — the result is simply never read.

    def dispatch_outer(self, worker_params: List[Any], *,
                       stats_vec: Optional[Any] = None,
                       phase2: Optional[dict] = None,
                       tid: Optional[int] = None,
                       template: Optional[Any] = None) -> Any:
        """Start the outer reduction; with ``stats_vec`` (the phase-1
        ``[colsum, b]`` f32 vector) the collective is fused: one wire
        operation reduces both payloads.  ``phase2`` (the deferred
        stats request carrying ``G_local``/``micro``) lets a real
        backend chain the five-moment phase-2 reduction onto the same
        in-flight window — the summed moments surface later through
        :meth:`pop_phase2_total`.  ``tid``/``template`` support
        multi-group backends: ranks outside trainer ``tid``'s group
        contribute zeros shaped like ``template`` (their group's
        result is discarded).  Returns an opaque handle."""
        raise NotImplementedError

    def wait_outer(self, handle) -> tuple:
        """Block on a :meth:`dispatch_outer` handle.  Returns
        ``(stacked, stats_total)``: the worker-stacked (or already
        reduced ``(1, ...)``) params pytree, and the SUM-reduced phase-1
        vector (None when no ``stats_vec`` was fused)."""
        raise NotImplementedError

    def note_real_compute(self, t0: float, dt: float, *,
                          tid: int = 0) -> None:
        """Record a wall-clock inner-compute window (perf_counter
        origin) so real-clock overlap is measurable against the
        in-flight collective spans.  Pricing-only backends ignore it."""

    def mean_scalar(self, value: float, *,
                    tid: Optional[int] = None) -> float:
        """Mean of a per-process scalar over trainer ``tid``'s workers
        (loss logging); identity on single-process backends.  Every
        rank calls it (lockstep) and receives the group's mean."""
        return value

    def merge_reducer(self):
        """Callable executing :func:`repro.core.mit.do_merge` /
        ``consolidate`` averages as a real cross-group collective —
        ``reduce(trainers, weights, *, kind, tid)`` returning the
        weighted parameter average replicated on every rank — or None
        when the pool lives in one process (the in-process
        ``merge_params`` already sees every replica)."""
        return None

    def pop_phase2_total(self) -> Optional[Any]:
        """Summed phase-2 moments vector from a fused
        :meth:`dispatch_outer` ``phase2`` chain (cleared on read), or
        None when the backend finished no fused phase-2."""
        return None

    def stats_reducer(self):
        """SUM all-reduce of a small 1-D f32 vector over every
        process, for the adaptive batch-stats composition — or None
        when all workers live in this process (the in-process
        estimators already see every shard)."""
        return None

    def broadcast_params(self, params: Any) -> Any:
        """Coordinator's params on every process (init sync / joins)."""
        return params

    def pop_measured(self) -> Optional[float]:
        """Wall-clock seconds the last ``outer_reduce`` actually spent
        on the wire, or None for backends that only price."""
        return None

    def pop_stats_measured(self) -> Optional[float]:
        """Wall-clock seconds the last stats reduction spent on the
        wire, or None for backends that only price.  A separate slot
        from :meth:`pop_measured`: under async policies a stats
        reduction and an outer collective can be in flight together."""
        return None

    def pop_merge_measured(self) -> Optional[float]:
        """Wall-clock seconds the last merge/consolidate collective
        spent on the wire, or None for backends that only price."""
        return None


class SimBackend(CollectiveBackend):
    """Analytic pricing + in-process execution — the classic runtime.

    Wraps a :class:`NetworkModel` or :class:`Topology` for the clock and
    stacks worker params locally for the numerics.  ``for_run`` deep-
    copies the network so caller-owned fabric schedules stay reusable
    (the same contract ``run_cluster`` has always had).
    """

    name = "sim"

    def __init__(self, network: Optional[NetworkModel] = None):
        self.network = network if network is not None else NetworkModel()

    def for_run(self) -> "SimBackend":
        return SimBackend(copy.deepcopy(self.network))

    # ---------------------------------------------------------- pricing
    def allreduce_time(self, payload_bytes, nodes, *, now=0.0):
        return self.network.allreduce_time(payload_bytes, nodes, now=now)

    def point_to_point_time(self, payload_bytes, src, dst, *, now=0.0):
        return self.network.point_to_point_time(payload_bytes, src, dst,
                                                now=now)

    def add_fabric_window(self, start, duration=None, *, bw_scale=1.0,
                          extra_latency=0.0, scope="all"):
        if not hasattr(self.network, "add_fabric_window"):
            raise ValueError(
                f"network model {type(self.network).__name__} does not "
                f"support fabric events")
        self.network.add_fabric_window(start, duration, bw_scale=bw_scale,
                                       extra_latency=extra_latency,
                                       scope=scope)

    def fabric_change_points(self):
        if hasattr(self.network, "fabric_change_points"):
            return self.network.fabric_change_points()
        return []

    # -------------------------------------------------------- execution
    def outer_reduce(self, worker_params):
        if any(wp is None for wp in worker_params):
            raise ValueError("SimBackend executes every worker in-process;"
                             " got a partial worker set")
        return jax.tree.map(lambda *xs: jnp.stack(xs), *worker_params)

    def dispatch_outer(self, worker_params, *, stats_vec=None,
                       phase2=None, tid=None, template=None):
        # The sim's "wire" is the priced clock, not real time: the stack
        # happens eagerly at dispatch and the handle is just the result.
        # A fused stats_vec reduces over the one process = identity sum;
        # phase2/tid/template are multi-process concerns (the sim holds
        # every worker and every trainer in-process).
        stats = None if stats_vec is None else jnp.asarray(stats_vec,
                                                           jnp.float32)
        return (self.outer_reduce(worker_params), stats)

    def wait_outer(self, handle):
        return handle


class JaxProcessBackend(CollectiveBackend):
    """Real multi-process execution over ``jax.distributed``.

    Construct *after* ``jax.distributed.initialize`` (see
    ``repro.cluster.launch_mp``, which spawns one CPU process per worker
    and elects process 0 as coordinator).  Worker m lives on process m;
    the outer reduction is a jitted ``shard_map`` whose mesh axes follow
    the pricing ``Topology``'s participant-pruned domain tree, so the
    per-axis ``lax.pmean`` chain lowers to grouped all-reduces per
    fabric level (leaf siblings first, bottleneck level last).  With a
    flat :class:`NetworkModel` — or an unbalanced participant tree — the
    mesh is one flat axis and the reduction a single all-reduce.
    Multi-trainer pools (``k > 1``) prepend a trainer-group axis:
    trainer t's workers are the rank block ``[t*M, (t+1)*M)``, outer
    syncs are grouped means over the fabric axes only, and merges run
    as global weighted psums (see :meth:`merge_reducer`).

    The analytic network still prices the simulated clock (reports stay
    comparable across backends); the wall-clock each collective actually
    took flows to ``ClusterReport.real_comm_time`` via
    :meth:`pop_measured`.  Works single-process too
    (``jax.process_count() == 1``): the mesh is this process's device
    and every collective degenerates to the identity, which is what the
    in-process smoke tests exercise.
    """

    name = "jax"

    def __init__(self, network: Optional[NetworkModel] = None):
        self.network = network if network is not None else NetworkModel()
        self.num_processes = jax.process_count()
        self.rank = jax.process_index()
        self._k = 1                  # trainer groups (validate sets it)
        self._M = 1                  # workers per group
        self._last_measured: Optional[float] = None
        self._last_stats_measured: Optional[float] = None
        self._last_merge_measured: Optional[float] = None
        self._last_phase2: Optional[Any] = None
        self._profiles: Optional[List[NodeProfile]] = None
        self._mesh = None
        self._axes: Optional[tuple] = None
        self._group_axes: Optional[tuple] = None
        self._reduce_jit = None
        self._allsum_jit = None
        self._warm: set = set()      # (shape, dtype) combos already compiled
        self._trace = None           # wall-clock span sink (attach_trace)
        self._trace_origin = 0.0     # perf_counter at attach -> span t=0

    def for_run(self) -> "JaxProcessBackend":
        run = object.__new__(JaxProcessBackend)
        run.__dict__.update(self.__dict__)
        run.network = copy.deepcopy(self.network)
        return run

    def bind(self, profiles):
        self._profiles = list(profiles)
        self._mesh = None            # topology of the run may differ

    def attach_trace(self, trace):
        """Wall-clock spans for every executed collective land in
        ``trace`` on the ``real`` clock, timestamped relative to the
        attach point (run start) — laid alongside the runtime's sim
        spans so simulated and measured wire time are comparable per
        collective."""
        self._trace = trace
        self._trace_origin = time.perf_counter()

    def _record_real(self, kind: str, t0: float, dt: float,
                     tid: int = 0) -> None:
        if self._trace is not None:
            rel = t0 - self._trace_origin
            self._trace.begin(tid, kind, rel, rel + dt, clock="real",
                              rank=self.rank)

    def validate(self, acfg, *, policy, k, M, scenario=(), autoscale=None):
        P = self.num_processes
        if policy not in ("sync", "async"):
            raise ValueError(
                f"JaxProcessBackend supports the sync/async policies, "
                f"not {policy!r} (elastic pools mutate in-process state)")
        if autoscale is not None:
            raise ValueError(
                "autoscaling scripts joins/leaves through the elastic "
                "in-process pool; JaxProcessBackend cannot grow or "
                "shrink its process set mid-run")
        if k * M != P:
            if k == 1:
                raise ValueError(
                    f"one worker per process: nodes_per_gpu={M} but "
                    f"{P} processes are initialized")
            raise ValueError(
                f"one worker per process: k={k} trainers x "
                f"nodes_per_gpu={M} need {k * M} processes, but "
                f"{P} are initialized")
        if acfg.adaptive and k != 1:
            raise ValueError(
                "adaptive batching reduces its statistics over the whole "
                "mesh, not per trainer group; multi-trainer (k > 1) pools "
                "run fixed-batch on JaxProcessBackend")
        if acfg.adaptive and P > 1 and acfg.stats_estimator != "microbatch":
            raise ValueError(
                "distributed adaptive batching composes each rank's "
                "microbatch-mean gradients through the stats all-reduce; "
                "the per-sample probe estimator is rank-local and would "
                "desynchronize the batch decision — run with "
                "stats_estimator='microbatch'")
        bad = {e.kind for e in scenario} & {"join", "leave"}
        if bad:
            raise ValueError(f"scenario events {sorted(bad)} need the "
                             f"elastic in-process pool")
        self._k = int(k)
        self._M = int(M)
        self._mesh = None            # group structure may have changed

    def _member(self, tid: Optional[int]) -> bool:
        """Rank-indexed group membership: trainer ``tid``'s workers are
        the contiguous rank block ``[tid*M, (tid+1)*M)``.  Pool surgery
        (merges) never moves ranks between groups — a merged-away
        trainer's ranks simply stop being members of any live tid."""
        if self._k == 1 or tid is None:
            return True
        return self.rank // self._M == tid

    # ---------------------------------------------------------- pricing
    def allreduce_time(self, payload_bytes, nodes, *, now=0.0):
        return self.network.allreduce_time(payload_bytes, nodes, now=now)

    def point_to_point_time(self, payload_bytes, src, dst, *, now=0.0):
        return self.network.point_to_point_time(payload_bytes, src, dst,
                                                now=now)

    def add_fabric_window(self, start, duration=None, *, bw_scale=1.0,
                          extra_latency=0.0, scope="all"):
        self.network.add_fabric_window(start, duration, bw_scale=bw_scale,
                                       extra_latency=extra_latency,
                                       scope=scope)

    def fabric_change_points(self):
        return self.network.fabric_change_points()

    # ------------------------------------------------------------- mesh
    def _balanced_shape(self, ptree):
        """(level shape, flat name order) of a participant tree if every
        sibling subtree has the same shape, else None -> flat mesh."""
        if ptree and all(isinstance(x, str) for x in ptree):
            return (len(ptree),), list(ptree)
        subs = [self._balanced_shape(c) for c in ptree]
        if any(s is None for s in subs):
            return None
        shapes = {s for s, _ in subs}
        if len(shapes) != 1:
            return None
        shape, _ = subs[0]
        return ((len(ptree),) + shape,
                [nm for _, order in subs for nm in order])

    def _build_mesh(self):
        import numpy as np
        from jax.sharding import Mesh

        if self._profiles is None:
            raise RuntimeError("backend not bound to profiles yet")
        P = self.num_processes
        names = [p.name for p in self._profiles[:P]]
        proc_of = {nm: i for i, nm in enumerate(names)}
        if self._k == 1:
            shape, order = (len(names),), list(names)
            if hasattr(self.network, "participant_tree"):
                spec = self._balanced_shape(
                    self.network.participant_tree(names))
                if spec is not None:
                    shape, order = spec
            axes = tuple(f"l{i}" for i in range(len(shape)))
            group_axes = axes
        else:
            # multi-trainer: a leading "t" axis indexes the disjoint
            # per-trainer process groups (trainer t = rank block
            # [t*M, (t+1)*M)); the fabric axes nest inside it when every
            # group's participant-pruned tree has the same shape, else
            # each group is one flat row.  Grouped reductions never name
            # "t", so a trainer's outer sync only touches its own block.
            k, M = self._k, self._M
            groups = [names[t * M:(t + 1) * M] for t in range(k)]
            sub = None
            if hasattr(self.network, "participant_tree"):
                specs = [self._balanced_shape(
                    self.network.participant_tree(g)) for g in groups]
                if (all(s is not None for s in specs)
                        and len({s[0] for s in specs}) == 1):
                    sub = (specs[0][0],
                           [nm for _, order in specs for nm in order])
            if sub is not None:
                shape, order = (k,) + sub[0], sub[1]
            else:
                shape, order = (k, M), [nm for g in groups for nm in g]
            axes = ("t",) + tuple(f"l{i}" for i in range(len(shape) - 1))
            group_axes = axes[1:]
        # device d belongs to process d.process_index; one device per
        # process under the launch_mp contract
        dev_of_proc = {}
        for d in jax.devices():
            dev_of_proc.setdefault(d.process_index, d)
        devs = np.array([dev_of_proc[proc_of[nm]] for nm in order])
        self._axes = axes
        self._group_axes = group_axes
        self._mesh = Mesh(devs.reshape(shape), axes)
        self._reduce_jit = None
        self._allsum_jit = None

    def _reducer(self):
        """Jitted mean-over-workers: pmean per *group* mesh axis,
        innermost (leaf siblings) to outermost (top bottleneck) — the
        hierarchical all-reduce schedule, for real.  With k > 1 the
        leading trainer axis is never reduced, so each group's row gets
        its own mean (non-member rows reduce their zeros to zeros)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh, axes, group_axes = self._mesh, self._axes, self._group_axes

        def mean_group(x):
            for ax in reversed(group_axes):
                x = jax.lax.pmean(x, ax)
            return x

        return jax.jit(shard_map(mean_group, mesh=mesh,
                                 in_specs=P(axes), out_specs=P(axes)))

    def _allsummer(self):
        """Jitted SUM over *every* mesh axis — the cross-group
        collective merges and the final consolidate ride.  Summing over
        the trainer axis too is what folds the groups' weighted
        replicas into one globally-replicated result."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh, axes = self._mesh, self._axes

        def sum_all(x):
            for ax in reversed(axes):
                x = jax.lax.psum(x, ax)
            return x

        return jax.jit(shard_map(sum_all, mesh=mesh,
                                 in_specs=P(axes), out_specs=P(axes)))

    def _ensure_jits(self):
        if self._mesh is None:
            self._build_mesh()
        if self._reduce_jit is None:
            self._reduce_jit = self._reducer()
        if self._allsum_jit is None:
            self._allsum_jit = self._allsummer()

    # -------------------------------------------------------- execution
    def local_workers(self, M, *, tid=None):
        if self.num_processes == 1 and M == 1:
            return [0]
        if self._k == 1:
            return [self.rank]
        return [self.rank % self._M] if self._member(tid) else []

    def _dispatch(self, tree, fn=None):
        """Lift the local worker onto the global mesh (leading worker
        axis sharded across every level axis) and *enqueue* the jitted
        reduction — no ready-wait, so the collective runs while the
        caller keeps computing (jax's async dispatch)."""
        from jax.experimental import multihost_utils
        from jax.sharding import PartitionSpec as P

        mesh, spec = self._mesh, P(self._axes)
        glob = multihost_utils.host_local_array_to_global_array(
            tree, mesh, spec)
        return jax.tree.map(self._reduce_jit if fn is None else fn, glob)

    def _collect(self, out):
        """Read a dispatched reduction back to host-local shards,
        blocking until the wire work is done."""
        from jax.experimental import multihost_utils
        from jax.sharding import PartitionSpec as P

        mesh, spec = self._mesh, P(self._axes)
        host = multihost_utils.global_array_to_host_local_array(
            out, mesh, spec)
        return jax.tree.map(jax.block_until_ready, host)

    def _execute(self, tree, fn=None):
        """Blocking dispatch+collect (warm-ups and the inline paths)."""
        return self._collect(self._dispatch(tree, fn))

    def outer_reduce(self, worker_params):
        local = [wp for wp in worker_params if wp is not None]
        if len(local) != 1:
            raise ValueError(f"expected exactly the local worker's "
                             f"params, got {len(local)} entries")
        self._ensure_jits()
        tree = jax.tree.map(lambda x: jnp.asarray(x)[None], local[0])
        sig = tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(tree))
        if sig not in self._warm:
            # run once untimed so trace/compile never lands in the
            # measured window (pmean is deterministic, and every rank
            # reaches this point in lockstep, so the extra collective is
            # identical everywhere); re-run below for the wire timing
            self._execute(tree)
            self._warm.add(sig)
        t0 = time.perf_counter()
        host = self._execute(tree)
        self._last_measured = time.perf_counter() - t0
        self._record_real("outer", t0, self._last_measured)
        # every shard now holds the global mean: a (1, ...) worker axis
        # that make_outer_step's mean passes through unchanged
        return host

    def dispatch_outer(self, worker_params, *, stats_vec=None,
                       phase2=None, tid=None, template=None):
        local = [wp for wp in worker_params if wp is not None]
        if self._member(tid):
            if len(local) != 1:
                raise ValueError(f"expected exactly the local worker's "
                                 f"params, got {len(local)} entries")
            tree = jax.tree.map(lambda x: jnp.asarray(x)[None], local[0])
        else:
            # outside trainer tid's group: participate in the (global)
            # wire operation with zeros shaped like the template — this
            # row's grouped mean is zeros and the runtime discards it
            if local:
                raise ValueError("rank outside the trainer's group "
                                 "computed worker params")
            if template is None:
                raise ValueError("non-member dispatch needs a params "
                                 "template")
            tree = jax.tree.map(
                lambda x: jnp.zeros((1,) + jnp.shape(x),
                                    jnp.asarray(x).dtype), template)
        self._ensure_jits()
        fused = stats_vec is not None
        if fused:
            # piggyback: the phase-1 [colsum, b] vector rides the same
            # wire operation as the params — one fused collective
            # instead of two gradient-order reductions per round
            tree = {"params": tree,
                    "stats": jnp.asarray(stats_vec, jnp.float32)[None]}
        sig = tuple((l.shape, str(l.dtype)) for l in jax.tree.leaves(tree))
        if sig not in self._warm:
            # compile with a blocking run outside any measured window
            # (lockstep on every rank: dispatch order is deterministic,
            # so the extra collective is identical everywhere)
            self._execute(tree)
            self._warm.add(sig)
        chain = (fused and phase2 is not None
                 and self.num_processes > 1)
        if chain:
            # the phase-2 five-moment reduction will chain onto this
            # window; warm its signature now so no compile lands inside
            ph2_sig = ((1, 5), "float32", "stats")
            if ph2_sig not in self._warm:
                self._execute(jnp.zeros((1, 5), jnp.float32))
                self._warm.add(ph2_sig)
        t0 = time.perf_counter()
        out = self._dispatch(tree)     # enqueued, NOT blocked on
        handle = {"out": out, "t0": t0, "fused": fused}
        if chain:
            # fold-time fusion (ROADMAP: overlap the phase-2 reduction
            # too): derive ḡ from the in-flight phase-1 result without
            # blocking — the ops below build on the enqueued buffers —
            # and chain the five shard moments as a second enqueued
            # collective on the same window.  wait_outer collects both;
            # the standalone fold-time stats sync is gone.
            from jax.experimental import multihost_utils
            from jax.sharding import PartitionSpec as P

            row = multihost_utils.global_array_to_host_local_array(
                out["stats"], self._mesh, P(self._axes))
            tot = row[0] * jnp.float32(self.num_processes)
            gbar = tot[:-1] / jnp.maximum(tot[-1], 1.0)
            from repro.core import batching
            m = batching.shard_moments(phase2["G_local"], gbar)
            handle["ph2"] = self._dispatch(m[None])
        return handle

    def wait_outer(self, handle):
        host = self._collect(handle["out"])
        if "ph2" in handle:
            row = self._collect(handle["ph2"])
            # mesh reduction is a mean over the P shards; the stats
            # composition protocol wants elementwise sums
            self._last_phase2 = row[0] * jnp.float32(self.num_processes)
        t0 = handle["t0"]
        dt = time.perf_counter() - t0
        self._last_measured = dt
        # the recorded span is the true in-flight window: dispatch ->
        # ready, spanning whatever inner compute ran in between (and
        # any chained phase-2 moments collective)
        self._record_real("piggyback" if handle["fused"] else "outer",
                          t0, dt)
        if handle["fused"]:
            # same mean -> sum rescale for the fused phase-1 vector
            stats_total = host["stats"][0] * jnp.float32(self.num_processes)
            return host["params"], stats_total
        return host, None

    def pop_phase2_total(self):
        v, self._last_phase2 = self._last_phase2, None
        return v

    def note_real_compute(self, t0, dt, *, tid=0):
        self._record_real("compute", t0, dt, tid=tid)

    def mean_scalar(self, value, *, tid=None):
        if self.num_processes == 1:
            return float(value)
        from jax.experimental import multihost_utils
        if self._k == 1:
            got = multihost_utils.process_allgather(
                jnp.asarray(value, jnp.float32))
            return float(jnp.mean(got))
        # group mean as a masked allgather-sum: members contribute
        # value/M, everyone else zero — every rank still joins the
        # collective (lockstep) and reads the same group mean
        contrib = (float(value) / self._M) if self._member(tid) else 0.0
        got = multihost_utils.process_allgather(
            jnp.asarray(contrib, jnp.float32))
        return float(jnp.sum(got))

    def merge_reducer(self):
        """Merges/consolidates as real cross-group collectives: member
        ranks contribute their trainer's replica scaled by
        ``weight/M`` (each of the M group ranks carries 1/M of the
        group's share), non-members contribute zeros, and one global
        ``psum`` folds both the weighted parameter sum and the total
        weight — the division lands the batch-weighted average
        replicated on every rank, exactly what Algorithm 2 computes
        in-process.  None when the pool lives in one process."""
        if self.num_processes == 1 or self._k == 1:
            return None

        def merge_reduce(trainers, weights, *, kind="merge", tid=0):
            self._ensure_jits()
            template = trainers[0].params
            mine, w = None, 0.0
            for t, wt in zip(trainers, weights):
                if self._member(t.tid):
                    mine, w = t.params, float(wt)
            if mine is None:
                tree = jax.tree.map(
                    lambda x: jnp.zeros((1,) + jnp.shape(x), jnp.float32),
                    template)
                wrow = 0.0
            else:
                wrow = w / float(self._M)
                scale = jnp.float32(wrow)
                tree = jax.tree.map(
                    lambda x: (jnp.asarray(x, jnp.float32) * scale)[None],
                    mine)
            payload = {"x": tree, "w": jnp.full((1,), wrow, jnp.float32)}
            sig = tuple((l.shape, str(l.dtype))
                        for l in jax.tree.leaves(payload)) + ("merge",)
            if sig not in self._warm:
                # compile outside the measured window (lockstep: every
                # rank reaches the merge event in the same order)
                self._execute(payload, self._allsum_jit)
                self._warm.add(sig)
            t0 = time.perf_counter()
            host = self._execute(payload, self._allsum_jit)
            dt = time.perf_counter() - t0
            self._last_merge_measured = (
                (self._last_merge_measured or 0.0) + dt)
            self._record_real(kind, t0, dt, tid=tid)
            wsum = host["w"][0]
            return jax.tree.map(
                lambda s, ref: (s[0] / wsum).astype(jnp.asarray(ref).dtype),
                host["x"], template)

        return merge_reduce

    def pop_merge_measured(self):
        m, self._last_merge_measured = self._last_merge_measured, None
        return m

    def stats_reducer(self):
        """Cross-process SUM of a small f32 vector, executed as the
        same per-fabric-level ``lax.pmean`` chain as the outer
        reduction (scaled back to a sum) — the batch-stats phases ride
        the mesh the pricing ``Topology`` defines.  None on a single
        process: the in-process estimator already sees every worker,
        and must stay bit-identical to the SimBackend."""
        if self.num_processes == 1:
            return None

        def reduce_sum(vec):
            if self._mesh is None:
                self._build_mesh()
            if self._reduce_jit is None:
                self._reduce_jit = self._reducer()
            tree = jnp.asarray(vec, jnp.float32)[None]
            sig = (tree.shape, str(tree.dtype), "stats")
            if sig not in self._warm:
                # compile outside the measured window (lockstep on
                # every rank, same as the outer warm-up)
                self._execute(tree)
                self._warm.add(sig)
            t0 = time.perf_counter()
            host = self._execute(tree)
            dt = time.perf_counter() - t0
            self._last_stats_measured = (
                (self._last_stats_measured or 0.0) + dt)
            self._record_real("stats", t0, dt)
            # the mesh reduction is a mean over the P workers; the
            # composition protocol wants elementwise sums
            return host[0] * jnp.float32(self.num_processes)

        return reduce_sum

    def pop_stats_measured(self):
        m = self._last_stats_measured
        self._last_stats_measured = None
        return m

    def broadcast_params(self, params):
        if self.num_processes == 1:
            return params
        from jax.experimental import multihost_utils
        return multihost_utils.broadcast_one_to_all(params)

    def pop_measured(self):
        m, self._last_measured = self._last_measured, None
        return m


__all__ = ["CollectiveBackend", "JaxProcessBackend", "SimBackend"]
