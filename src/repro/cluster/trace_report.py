"""CLI: summarize and validate cluster-runtime Perfetto traces.

Reads one or more trace JSON files produced by
:meth:`repro.cluster.trace.Trace.to_perfetto` (``run_cluster(trace=)``,
``launch_mp --trace``, ``cluster_bench --trace``) and prints, per file,
the per-trainer utilization ledger (busy / comm-blocked / idle seconds,
partitioning each trainer's alive window), the overlap fraction broken
down by collective kind, and the longest spans::

    PYTHONPATH=src python -m repro.cluster.trace_report trace.json
    PYTHONPATH=src python -m repro.cluster.trace_report --validate *.json

``--validate`` runs the schema check (span kinds, clock tags,
timestamps, alive windows, schema version) and exits nonzero on any
violation — CI runs it on every lane-produced trace so schema drift
fails fast instead of silently breaking downstream consumers.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.cluster.trace import Trace, validate_perfetto


def report(tr: Trace, *, top: int = 8, out=sys.stdout) -> None:
    sim = tr.sim_spans()
    real = tr.real_spans()
    print(f"  {len(sim)} sim spans, {len(real)} real spans, "
          f"{len(tr.events)} instants, {len(tr.alive)} trainers, "
          f"end t={tr.finalized_at}", file=out)
    ledger = tr.utilization()
    print("  tid      alive_s       busy        blocked      idle",
          file=out)
    for tid, led in ledger.items():

        def pct(x: float) -> str:
            return (f"{x:9.4f} ({x / led['alive'] * 100:3.0f}%)"
                    if led["alive"] > 0 else f"{x:9.4f} (  -%)")

        print(f"  {tid:3d} {led['alive']:10.4f} {pct(led['busy'])} "
              f"{pct(led['blocked'])} {pct(led['idle'])}", file=out)
    summ = tr.utilization_summary()
    by_kind = tr.overlap_by_kind()
    kinds = ", ".join(
        f"{k}: {v['frac']:.3f} of {v['total']:.4f}s"
        for k, v in by_kind.items() if v["total"] > 0) or "none"
    print(f"  utilization={summ['utilization']:.4f} "
          f"(blocked={summ['blocked_frac']:.4f}, "
          f"idle={summ['idle_frac']:.4f})", file=out)
    print(f"  overlap_frac={tr.overlap_fraction():.4f}  [{kinds}]",
          file=out)
    if real:
        wall = sum(s.duration for s in real)
        print(f"  real wall-clock in collectives: {wall:.6f}s over "
              f"{len(real)} spans", file=out)
    longest = sorted(tr.spans, key=lambda s: -s.duration)[:top]
    print(f"  top {len(longest)} spans by duration:", file=out)
    for s in longest:
        print(f"    {s.clock:4s} {s.kind:8s} tid={s.tid:3d} "
              f"[{s.t0:.4f}, {s.t1:.4f}] {s.duration:.4f}s "
              f"{s.payload}", file=out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", metavar="TRACE_JSON",
                    help="Perfetto trace file(s) from Trace.to_perfetto")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check every file; nonzero exit on any "
                         "violation (CI's trace-schema gate)")
    ap.add_argument("--top", type=int, default=8,
                    help="longest spans to print per file")
    args = ap.parse_args(argv)

    bad = 0
    for path in args.paths:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({e})", file=sys.stderr)
            bad += 1
            continue
        problems = validate_perfetto(data)
        if problems:
            print(f"{path}: INVALID", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            bad += 1
            continue
        if args.validate:
            n = sum(1 for e in data["traceEvents"]
                    if e.get("ph") in ("X", "i"))
            print(f"{path}: schema OK ({n} events)")
            continue
        print(f"{path}:")
        report(Trace.from_perfetto(data), top=args.top)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
