"""Structured span/event trace for the cluster runtime.

The event loop in ``repro.cluster.runtime`` only reports aggregate
scalars (``ClusterReport``); this module records *where* the time goes:
one :class:`Span` per inner-compute block, outer collective, batch-stats
reduction, join transfer and fabric window, plus instant
:class:`TraceEvent` annotations (re-pricings, merges, joins, leaves,
slowdowns, autoscale actions and predicted batch decisions).  Two clocks coexist — ``sim`` spans carry the runtime's
simulated timestamps, ``real`` spans carry wall-clock seconds measured
inside an execution backend's collectives (``JaxProcessBackend``) — so
the simulated schedule and the machine's actual behavior can be laid
side by side on the same timeline.

Derived metrics
---------------
:meth:`Trace.utilization`
    Per-trainer ledger: every trainer's alive window is partitioned into
    *busy* (inner compute in flight), *comm-blocked* (a collective or
    join transfer in flight with no concurrent compute) and *idle*
    seconds.  ``busy + blocked + idle == alive`` is asserted — the
    ledger is a partition, not an approximation.
:meth:`Trace.overlap_fraction`
    The ROADMAP item-1 metric: collective in-flight time coincident
    with the same trainer's inner compute, divided by total collective
    time.  The sync policy scores exactly 0.0 (every collective is a
    barrier); async scores > 0 wherever an outer all-reduce hides
    behind the next round's compute.  Computable today for the
    simulated schedule and, via ``real`` spans, ready for the
    truly-overlapped real backend.
:meth:`Trace.to_perfetto`
    Chrome-trace/Perfetto JSON (load in https://ui.perfetto.dev); see
    ``repro.cluster.trace_report`` for the CLI that prints the ledger
    and validates the schema.

Recording is strictly opt-in: ``run_cluster(trace=Trace())``.  With the
default ``trace=None`` the runtime's instrumentation points are single
``if`` checks and nothing is allocated — the golden-trace digests of
``tests/test_scenarios.py`` are unchanged by the instrumentation.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: schema version stamped into every Perfetto export; bump on any
#: breaking change to span kinds / required fields so stale consumers
#: fail loudly in ``trace_report --validate``
TRACE_SCHEMA = 1

#: span kinds the runtime emits on the simulated clock ("piggyback" is
#: the fused outer+phase-1-stats collective of the async adaptive path)
SIM_SPAN_KINDS = ("compute", "outer", "stats", "xfer", "fabric",
                  "piggyback")
#: span kinds an execution backend emits on the wall clock: collective
#: in-flight windows (dispatch -> ready) plus the inner-compute windows
#: the runtime notes so real-clock overlap is measurable; merge /
#: consolidate are the cross-group pool collectives of multi-trainer
#: (k > 1) runs
REAL_SPAN_KINDS = ("outer", "stats", "piggyback", "compute", "merge",
                   "consolidate")
#: instant-event kinds ("autoscale" marks an ElasticPolicy scaling
#: action, "predict" a batch decision the growth predictor supplied
#: without a stats reduction)
EVENT_KINDS = ("reprice", "join", "leave", "merge", "slowdown",
               "preempt", "autoscale", "predict")
#: span kinds that count as "a collective in flight" for the
#: utilization ledger and the overlap fraction
COMM_KINDS = ("outer", "stats", "xfer", "piggyback")

#: synthetic track id for fabric-window spans (not owned by a trainer)
FABRIC_TID = -1


@dataclass
class Span:
    """One timed block.  ``t1`` may be ``None`` while still open; the
    runtime closes every span it begins (``Trace.finalize`` closes any
    survivor at the end of the run)."""

    tid: int
    kind: str
    t0: float
    t1: Optional[float] = None
    clock: str = "sim"
    payload: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0


@dataclass
class TraceEvent:
    """Instant annotation (zero duration) on a trainer's track."""

    tid: int
    kind: str
    t: float
    payload: Dict[str, Any] = field(default_factory=dict)


# ------------------------------------------------- interval arithmetic

def _union(intervals: Sequence[Tuple[float, float]]
           ) -> List[Tuple[float, float]]:
    """Merge possibly-overlapping [a, b) intervals into a sorted
    disjoint union."""
    ivs = sorted((a, b) for a, b in intervals if b > a)
    out: List[Tuple[float, float]] = []
    for a, b in ivs:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def _clip(intervals: Sequence[Tuple[float, float]], lo: float,
          hi: float) -> List[Tuple[float, float]]:
    return [(max(a, lo), min(b, hi)) for a, b in intervals
            if min(b, hi) > max(a, lo)]


def _total(intervals: Sequence[Tuple[float, float]]) -> float:
    return sum(b - a for a, b in intervals)


def _subtract(a: Sequence[Tuple[float, float]],
              b: Sequence[Tuple[float, float]]
              ) -> List[Tuple[float, float]]:
    """Disjoint-union ``a`` minus disjoint-union ``b`` (both sorted)."""
    out: List[Tuple[float, float]] = []
    bs = list(b)
    for lo, hi in a:
        cur = lo
        for b0, b1 in bs:
            if b1 <= cur or b0 >= hi:
                continue
            if b0 > cur:
                out.append((cur, b0))
            cur = max(cur, b1)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
    return out


def _overlap_total(interval: Tuple[float, float],
                   union: Sequence[Tuple[float, float]]) -> float:
    a, b = interval
    return sum(min(b, u1) - max(a, u0) for u0, u1 in union
               if min(b, u1) > max(a, u0))


class Trace:
    """Span/event recorder the runtime (and backends) write into."""

    def __init__(self) -> None:
        self.spans: List[Span] = []
        self.events: List[TraceEvent] = []
        #: tid -> [birth, death]; death is None while alive
        self.alive: Dict[int, List[Optional[float]]] = {}
        self.finalized_at: Optional[float] = None

    # ------------------------------------------------------- recording
    def begin(self, tid: int, kind: str, t0: float,
              t1: Optional[float] = None, *, clock: str = "sim",
              **payload: Any) -> Span:
        span = Span(tid=tid, kind=kind, t0=t0, t1=t1, clock=clock,
                    payload=payload)
        self.spans.append(span)
        return span

    def end(self, span: Optional[Span], t1: float,
            **payload: Any) -> None:
        if span is None:
            return
        span.t1 = t1
        span.payload.update(payload)

    def instant(self, tid: int, kind: str, t: float,
                **payload: Any) -> None:
        self.events.append(TraceEvent(tid=tid, kind=kind, t=t,
                                      payload=payload))

    def trainer_alive(self, tid: int, t0: float) -> None:
        self.alive.setdefault(tid, [t0, None])

    def trainer_dead(self, tid: int, t1: float) -> None:
        if tid in self.alive and self.alive[tid][1] is None:
            self.alive[tid][1] = t1

    def finalize(self, t_end: float) -> None:
        """Close every still-open span and alive window at ``t_end``
        (end of the run)."""
        self.finalized_at = t_end
        for s in self.spans:
            if s.t1 is None:
                s.t1 = max(t_end, s.t0)
        for w in self.alive.values():
            if w[1] is None:
                w[1] = max(t_end, w[0])

    # --------------------------------------------------------- queries
    def sim_spans(self, kinds: Optional[Sequence[str]] = None
                  ) -> List[Span]:
        return [s for s in self.spans if s.clock == "sim"
                and (kinds is None or s.kind in kinds)]

    def real_spans(self, kinds: Optional[Sequence[str]] = None
                   ) -> List[Span]:
        return [s for s in self.spans if s.clock == "real"
                and (kinds is None or s.kind in kinds)]

    def _spans_on(self, clock: str, kinds: Optional[Sequence[str]] = None
                  ) -> List[Span]:
        return (self.sim_spans(kinds) if clock == "sim"
                else self.real_spans(kinds))

    def _busy_union(self, clock: str = "sim"
                    ) -> Dict[int, List[Tuple[float, float]]]:
        per: Dict[int, List[Tuple[float, float]]] = {}
        for s in self._spans_on(clock, ("compute",)):
            per.setdefault(s.tid, []).append((s.t0, s.t1))
        return {tid: _union(ivs) for tid, ivs in per.items()}

    def utilization(self) -> Dict[int, Dict[str, float]]:
        """Per-trainer ledger: absolute seconds of ``busy`` (inner
        compute), ``blocked`` (a collective/transfer in flight and no
        compute running) and ``idle``, partitioning the ``alive``
        window exactly (asserted)."""
        busy_u = self._busy_union()
        comm_ivs: Dict[int, List[Tuple[float, float]]] = {}
        for s in self.sim_spans(COMM_KINDS):
            comm_ivs.setdefault(s.tid, []).append((s.t0, s.t1))
        ledger: Dict[int, Dict[str, float]] = {}
        for tid, (t0, t1) in sorted(self.alive.items()):
            alive = max(t1 - t0, 0.0)
            busy = _clip(busy_u.get(tid, []), t0, t1)
            comm = _clip(_union(comm_ivs.get(tid, [])), t0, t1)
            blocked = _subtract(comm, busy)
            idle = _subtract([(t0, t1)], _union(list(busy)
                                                + list(blocked)))
            led = {"alive": alive, "busy": _total(busy),
                   "blocked": _total(blocked), "idle": _total(idle)}
            parts = led["busy"] + led["blocked"] + led["idle"]
            if abs(parts - alive) > 1e-9 * max(alive, 1.0):
                raise AssertionError(
                    f"trainer {tid} ledger does not partition its alive "
                    f"span: busy+blocked+idle={parts!r} != alive={alive!r}")
            ledger[tid] = led
        return ledger

    def utilization_summary(self) -> Dict[str, float]:
        """Fleet aggregate of :meth:`utilization`: fractions of total
        alive trainer-seconds.  ``utilization`` is the busy fraction."""
        ledger = self.utilization()
        alive = sum(l["alive"] for l in ledger.values())
        if alive <= 0.0:
            return {"utilization": 0.0, "busy_frac": 0.0,
                    "blocked_frac": 0.0, "idle_frac": 0.0}
        busy = sum(l["busy"] for l in ledger.values())
        blocked = sum(l["blocked"] for l in ledger.values())
        idle = sum(l["idle"] for l in ledger.values())
        return {"utilization": busy / alive, "busy_frac": busy / alive,
                "blocked_frac": blocked / alive,
                "idle_frac": idle / alive}

    def overlap_fraction(self,
                         kinds: Sequence[str] = ("outer", "stats",
                                                 "piggyback"),
                         *, clock: str = "sim") -> float:
        """Collective in-flight time coincident with the same trainer's
        inner compute, over total collective time (ROADMAP item 1).
        Standalone ``stats`` reductions are in the denominator on
        purpose: they gate the round boundary when not piggybacked, so
        their zero overlap is the measured cost the Lau-style fusing
        removes.  ``clock="real"`` scores the *measured* wall-clock
        windows instead — collective in-flight spans (dispatch ->
        ready) against the noted inner-compute spans — so a truly
        nonblocking backend shows real overlap, not just a simulated
        schedule that claims it."""
        busy_u = self._busy_union(clock)
        total = overlap = 0.0
        for s in self._spans_on(clock, kinds):
            total += s.duration
            overlap += _overlap_total((s.t0, s.t1),
                                      busy_u.get(s.tid, []))
        return overlap / total if total > 0.0 else 0.0

    def overlap_by_kind(self, *, clock: str = "sim"
                        ) -> Dict[str, Dict[str, float]]:
        """Per-kind breakdown of :meth:`overlap_fraction`."""
        out: Dict[str, Dict[str, float]] = {}
        busy_u = self._busy_union(clock)
        for kind in ("outer", "stats", "xfer", "piggyback"):
            total = overlap = 0.0
            for s in self._spans_on(clock, (kind,)):
                total += s.duration
                overlap += _overlap_total((s.t0, s.t1),
                                          busy_u.get(s.tid, []))
            out[kind] = {"total": total, "overlap": overlap,
                         "frac": overlap / total if total > 0 else 0.0}
        return out

    # --------------------------------------------------------- digests
    def _sim_schema(self) -> list:
        """Canonical, JSON-stable view of the simulated schedule: every
        sim span and instant with its payload.  Real spans are excluded
        — the digest must agree between ``SimBackend`` and
        ``JaxProcessBackend`` runs of the same fixture."""
        spans = [[s.tid, s.kind, s.t0, s.t1,
                  dict(sorted(s.payload.items()))]
                 for s in self.sim_spans()]
        events = [[e.tid, e.kind, e.t, dict(sorted(e.payload.items()))]
                  for e in self.events]
        alive = {str(t): w for t, w in sorted(self.alive.items())}
        return [spans, events, alive]

    def sim_digest(self) -> str:
        blob = json.dumps(self._sim_schema(), sort_keys=True,
                          default=float)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -------------------------------------------------------- perfetto
    def to_perfetto(self) -> dict:
        """Chrome-trace JSON (Perfetto-loadable).  pid 0 carries the
        simulated clock, pid 1 the measured wall clock; thread ids are
        trainer ids (fabric windows on a synthetic track).  ``ts`` is
        microseconds, as the format requires."""
        tids = sorted(set(self.alive)
                      | {s.tid for s in self.spans if s.tid != FABRIC_TID}
                      | {e.tid for e in self.events if e.tid != FABRIC_TID})
        track = {tid: tid for tid in tids}
        track[FABRIC_TID] = (max(tids) + 1) if tids else 0
        evs: List[dict] = []
        for pid, name in ((0, "sim"), (1, "real")):
            evs.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": name}})
        for tid in tids:
            evs.append({"ph": "M", "name": "thread_name", "pid": 0,
                        "tid": track[tid],
                        "args": {"name": f"trainer {tid}"}})
        evs.append({"ph": "M", "name": "thread_name", "pid": 0,
                    "tid": track[FABRIC_TID], "args": {"name": "fabric"}})
        # args carry the exact second-resolution endpoints (t0/t1/t):
        # the µs ts/dur the format requires are lossy under float
        # round-trip, and from_perfetto must rebuild digest-identically
        for s in self.spans:
            pid = 0 if s.clock == "sim" else 1
            evs.append({"ph": "X", "name": s.kind, "cat": s.clock,
                        "pid": pid, "tid": track.get(s.tid, s.tid),
                        "ts": s.t0 * 1e6, "dur": s.duration * 1e6,
                        "args": dict(s.payload, trace_tid=s.tid,
                                     t0=s.t0, t1=s.t1)})
        for e in self.events:
            evs.append({"ph": "i", "name": e.kind, "cat": "sim",
                        "pid": 0, "tid": track.get(e.tid, e.tid),
                        "ts": e.t * 1e6, "s": "t",
                        "args": dict(e.payload, trace_tid=e.tid, t=e.t)})
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {
                    "schema": TRACE_SCHEMA,
                    "producer": "repro.cluster.trace",
                    "alive": {str(t): list(w)
                              for t, w in sorted(self.alive.items())},
                    "finalized_at": self.finalized_at}}

    @classmethod
    def from_perfetto(cls, data: dict) -> "Trace":
        """Rebuild a Trace from :meth:`to_perfetto` output (the
        ``trace_report`` CLI path).  Raises ``ValueError`` on schema
        violations — run :func:`validate_perfetto` first for a full
        problem list instead of a first-error exception."""
        problems = validate_perfetto(data)
        if problems:
            raise ValueError("invalid trace JSON:\n  "
                             + "\n  ".join(problems))
        tr = cls()
        for ev in data["traceEvents"]:
            if ev["ph"] == "X":
                args = ev["args"]
                payload = {k: v for k, v in args.items()
                           if k not in ("trace_tid", "t0", "t1")}
                tr.spans.append(Span(
                    tid=args["trace_tid"], kind=ev["name"],
                    t0=args.get("t0", ev["ts"] / 1e6),
                    t1=args.get("t1", (ev["ts"] + ev["dur"]) / 1e6),
                    clock=ev["cat"], payload=payload))
            elif ev["ph"] == "i":
                args = ev["args"]
                payload = {k: v for k, v in args.items()
                           if k not in ("trace_tid", "t")}
                tr.events.append(TraceEvent(
                    tid=args["trace_tid"], kind=ev["name"],
                    t=args.get("t", ev["ts"] / 1e6), payload=payload))
        other = data["otherData"]
        tr.alive = {int(t): list(w) for t, w in other["alive"].items()}
        tr.finalized_at = other.get("finalized_at")
        return tr


def validate_perfetto(data: Any) -> List[str]:
    """Schema check for :meth:`Trace.to_perfetto` output; returns a
    list of human-readable problems (empty means valid)."""
    probs: List[str] = []
    if not isinstance(data, dict):
        return ["top level is not a JSON object"]
    other = data.get("otherData")
    if not isinstance(other, dict):
        probs.append("missing otherData block")
        other = {}
    if other.get("schema") != TRACE_SCHEMA:
        probs.append(f"schema version {other.get('schema')!r} != "
                     f"expected {TRACE_SCHEMA}")
    alive = other.get("alive")
    if not isinstance(alive, dict):
        probs.append("otherData.alive missing or not an object")
        alive = {}
    for t, w in alive.items():
        if (not isinstance(w, list) or len(w) != 2
                or any(not isinstance(x, (int, float)) for x in w)
                or w[1] < w[0]):
            probs.append(f"alive window for trainer {t} malformed: {w!r}")
    evs = data.get("traceEvents")
    if not isinstance(evs, list):
        return probs + ["traceEvents missing or not a list"]
    span_tids = set()
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict) or "ph" not in ev:
            probs.append(f"traceEvents[{i}] is not a phase event")
            continue
        if ev["ph"] == "M":
            continue
        if ev["ph"] not in ("X", "i"):
            probs.append(f"traceEvents[{i}] has unknown phase "
                         f"{ev['ph']!r}")
            continue
        args = ev.get("args")
        if not isinstance(args, dict) or "trace_tid" not in args:
            probs.append(f"traceEvents[{i}] missing args.trace_tid")
            continue
        if ev["ph"] == "X":
            clock = ev.get("cat")
            allowed = (SIM_SPAN_KINDS if clock == "sim"
                       else REAL_SPAN_KINDS if clock == "real" else None)
            if allowed is None:
                probs.append(f"traceEvents[{i}] has unknown clock "
                             f"{clock!r}")
            elif ev.get("name") not in allowed:
                probs.append(f"traceEvents[{i}] has unknown {clock} "
                             f"span kind {ev.get('name')!r}")
            if not isinstance(ev.get("ts"), (int, float)) \
                    or not isinstance(ev.get("dur"), (int, float)) \
                    or ev.get("dur", 0) < 0 or ev.get("ts", 0) < 0:
                probs.append(f"traceEvents[{i}] has malformed ts/dur")
            if (clock == "sim" and ev.get("name") != "fabric"
                    and args["trace_tid"] != FABRIC_TID):
                span_tids.add(args["trace_tid"])
        else:
            if ev.get("name") not in EVENT_KINDS:
                probs.append(f"traceEvents[{i}] has unknown event kind "
                             f"{ev.get('name')!r}")
            if not isinstance(ev.get("ts"), (int, float)):
                probs.append(f"traceEvents[{i}] has malformed ts")
    known = {int(t) for t in alive} if not probs else None
    if known is not None:
        orphans = {t for t in span_tids if t not in known}
        if orphans:
            probs.append(f"sim spans reference trainers with no alive "
                         f"window: {sorted(orphans)}")
    return probs


__all__ = ["COMM_KINDS", "EVENT_KINDS", "FABRIC_TID", "REAL_SPAN_KINDS",
           "SIM_SPAN_KINDS", "Span", "TRACE_SCHEMA", "Trace",
           "TraceEvent", "validate_perfetto"]
