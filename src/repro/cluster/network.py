"""Latency + bandwidth network cost model.

Extends the byte accounting of ``repro.core.comms`` into *time*: a ring
all-reduce over a set of :class:`~repro.cluster.node.NodeProfile`s is
bottlenecked by the slowest participating link and pays per-hop latency
on each of its 2(p−1) steps.  The cluster runtime uses this to decide
how long an outer sync keeps a trainer (sync policy) or the wire (async
policy) busy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.comms import TimedCommsMeter, ring_allreduce_time
from repro.cluster.node import DEFAULT_LATENCY, NodeProfile


@dataclass
class NetworkModel:
    """Cost model for collectives among virtual nodes.

    ``bw_scale``/``extra_latency`` let scenarios degrade the fabric
    globally (congestion) without touching per-node profiles.
    """

    bw_scale: float = 1.0
    extra_latency: float = 0.0

    def allreduce_time(self, payload_bytes: float,
                       nodes: Sequence[NodeProfile]) -> float:
        p = len(nodes)
        if p <= 1:
            return 0.0
        bw = min(n.link_bw for n in nodes) * self.bw_scale
        lat = max(n.link_latency for n in nodes) + self.extra_latency
        return ring_allreduce_time(payload_bytes, p, bw, lat)

    def point_to_point_time(self, payload_bytes: float, src: NodeProfile,
                            dst: NodeProfile) -> float:
        """One-directional transfer (elastic join: shipping params to a
        fresh trainer)."""
        bw = min(src.link_bw, dst.link_bw) * self.bw_scale
        lat = max(src.link_latency, dst.link_latency) + self.extra_latency
        return lat + payload_bytes / max(bw, 1.0)


__all__ = ["NetworkModel", "TimedCommsMeter", "ring_allreduce_time",
           "DEFAULT_LATENCY"]
