"""Network cost models: flat ring and pod-aware topology.

Extends the byte accounting of ``repro.core.comms`` into *time*.  Two
models share one interface (``allreduce_time`` / ``point_to_point_time``
/ ``add_fabric_window``, all taking a ``now``):

:class:`NetworkModel`
    The flat model: one ring over all participants, bottlenecked by the
    slowest link.  Kept as the topology-oblivious baseline.
:class:`Topology`
    Nodes grouped into pods with fast intra-pod links and explicit,
    slower cross-pod bottleneck paths.  Collectives spanning pods are
    priced by :func:`~repro.core.comms.hierarchical_allreduce_time`
    (per-pod reduce-scatter, cross-pod shard exchange, per-pod
    all-gather).

Both carry time-varying fabric state: a :class:`FabricSchedule` is a
baseline ``bw_scale``/``extra_latency`` plus piecewise-constant
:class:`FabricWindow`\\ s, so scenarios can open bursty congestion
windows or partition pods without touching per-node profiles.  The
cluster runtime re-prices in-flight collectives at every window edge.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.comms import (TimedCommsMeter, hierarchical_allreduce_time,
                              ring_allreduce_time)
from repro.cluster.node import DEFAULT_LATENCY, NodeProfile

#: valid scopes for fabric windows (Topology distinguishes intra/inter;
#: the flat NetworkModel has a single fabric and treats them alike)
FABRIC_SCOPES = ("all", "intra", "inter")


@dataclass
class FabricWindow:
    """Fabric degradation active inside [start, end): link bandwidth is
    multiplied by ``bw_scale`` and every hop pays ``extra_latency``."""

    start: float
    end: float
    bw_scale: float = 1.0
    extra_latency: float = 0.0


@dataclass
class FabricSchedule:
    """Piecewise-constant time-varying fabric state.

    ``bw_scale``/``extra_latency`` are the always-on baseline;
    overlapping windows compose (scales multiply, latencies add), so a
    congestion burst during a partition degrades the fabric further.
    """

    bw_scale: float = 1.0
    extra_latency: float = 0.0
    windows: List[FabricWindow] = field(default_factory=list)

    def add_window(self, start: float, duration: Optional[float] = None, *,
                   bw_scale: float = 1.0,
                   extra_latency: float = 0.0) -> FabricWindow:
        """Open a window at ``start``; ``duration`` of None (or <= 0)
        means the degradation is permanent."""
        if bw_scale <= 0.0:
            raise ValueError(f"bw_scale must be positive, got {bw_scale}")
        if extra_latency < 0.0:
            raise ValueError(
                f"extra_latency must be >= 0, got {extra_latency}")
        end = (start + duration if duration is not None and duration > 0
               else math.inf)
        w = FabricWindow(start, end, bw_scale, extra_latency)
        self.windows.append(w)
        return w

    def at(self, now: float) -> Tuple[float, float]:
        """(bandwidth scale, extra latency) in effect at time ``now``."""
        scale, extra = self.bw_scale, self.extra_latency
        for w in self.windows:
            if w.start <= now < w.end:
                scale *= w.bw_scale
                extra += w.extra_latency
        return scale, extra

    def change_points(self) -> List[float]:
        """Finite window edges, sorted — the instants pricing changes."""
        pts = {w.start for w in self.windows}
        pts |= {w.end for w in self.windows if math.isfinite(w.end)}
        return sorted(pts)


def _check_scope(scope: str) -> None:
    if scope not in FABRIC_SCOPES:
        raise ValueError(f"scope must be one of {FABRIC_SCOPES}, "
                         f"got {scope!r}")


@dataclass
class NetworkModel:
    """Flat cost model: every collective is one ring over the global
    min-bandwidth link.

    ``bw_scale``/``extra_latency`` seed the baseline of the fabric
    schedule (kept as constructor arguments for compatibility);
    scenarios add time-windowed degradations on top via
    :meth:`add_fabric_window`.
    """

    bw_scale: float = 1.0
    extra_latency: float = 0.0
    fabric: Optional[FabricSchedule] = None

    def __post_init__(self) -> None:
        if self.fabric is None:
            self.fabric = FabricSchedule(bw_scale=self.bw_scale,
                                         extra_latency=self.extra_latency)
        elif self.bw_scale != 1.0 or self.extra_latency != 0.0:
            raise ValueError(
                "pass the baseline via the FabricSchedule, not both a "
                "fabric and bw_scale/extra_latency")

    def add_fabric_window(self, start: float,
                          duration: Optional[float] = None, *,
                          bw_scale: float = 1.0, extra_latency: float = 0.0,
                          scope: str = "all") -> None:
        _check_scope(scope)          # flat fabric: every scope is the wire
        self.fabric.add_window(start, duration, bw_scale=bw_scale,
                               extra_latency=extra_latency)

    def fabric_change_points(self) -> List[float]:
        return self.fabric.change_points()

    def allreduce_time(self, payload_bytes: float,
                       nodes: Sequence[NodeProfile], *,
                       now: float = 0.0) -> float:
        p = len(nodes)
        if p <= 1:
            return 0.0
        scale, extra = self.fabric.at(now)
        bw = min(n.link_bw for n in nodes) * scale
        if bw <= 0.0:
            raise ValueError(
                f"non-positive effective bandwidth {bw!r} among "
                f"{[n.name for n in nodes]}; check link_bw / bw_scale")
        lat = max(n.link_latency for n in nodes) + extra
        return ring_allreduce_time(payload_bytes, p, bw, lat)

    def point_to_point_time(self, payload_bytes: float, src: NodeProfile,
                            dst: NodeProfile, *, now: float = 0.0) -> float:
        """One-directional transfer (elastic join: shipping params to a
        fresh trainer)."""
        scale, extra = self.fabric.at(now)
        bw = min(src.link_bw, dst.link_bw) * scale
        if bw <= 0.0:
            raise ValueError(
                f"non-positive effective bandwidth {bw!r} between "
                f"{src.name!r} and {dst.name!r}; check link_bw / bw_scale")
        lat = max(src.link_latency, dst.link_latency) + extra
        return lat + payload_bytes / bw


@dataclass
class Topology:
    """Pods of nodes with fast intra-pod links and slower explicit
    cross-pod bottleneck paths.

    ``pods`` lists node *names* per pod; collectives are routed per-pod
    reduce-scatter -> cross-pod shard exchange -> per-pod all-gather,
    which reduces to the plain ring whenever all participants share a
    pod.  ``inter_bw`` is the bandwidth of one cross-pod path (a node's
    route to its peers in other pods; the concurrent per-node shard
    rings each get one path), typically well below the intra-pod link
    speed.  ``intra_fabric`` and ``inter_fabric`` carry independent
    time-varying degradations, so a congestion scenario can squeeze
    only the cross-pod paths (scope ``"inter"``) while intra-pod
    traffic stays fast.
    """

    pods: List[List[str]]
    inter_bw: float
    inter_latency: float = DEFAULT_LATENCY
    intra_fabric: FabricSchedule = field(default_factory=FabricSchedule)
    inter_fabric: FabricSchedule = field(default_factory=FabricSchedule)

    def __post_init__(self) -> None:
        if self.inter_bw <= 0.0:
            raise ValueError(f"inter_bw must be positive, got "
                             f"{self.inter_bw}")
        self._pod_of: Dict[str, int] = {}
        for pi, pod in enumerate(self.pods):
            for name in pod:
                if name in self._pod_of:
                    raise ValueError(f"node {name!r} appears in more than "
                                     f"one pod")
                self._pod_of[name] = pi

    @classmethod
    def from_profiles(cls, profiles: Sequence[NodeProfile], *,
                      inter_bw: float,
                      inter_latency: float = DEFAULT_LATENCY) -> "Topology":
        """Group profiles by their ``pod`` attribute (None -> pod 0)."""
        pods: Dict[int, List[str]] = {}
        for p in profiles:
            pods.setdefault(p.pod if p.pod is not None else 0,
                            []).append(p.name)
        return cls(pods=[pods[k] for k in sorted(pods)], inter_bw=inter_bw,
                   inter_latency=inter_latency)

    def pod_of(self, name: str) -> int:
        try:
            return self._pod_of[name]
        except KeyError:
            raise ValueError(f"node {name!r} is not in the topology "
                             f"(known: {sorted(self._pod_of)})") from None

    def add_fabric_window(self, start: float,
                          duration: Optional[float] = None, *,
                          bw_scale: float = 1.0, extra_latency: float = 0.0,
                          scope: str = "all") -> None:
        _check_scope(scope)
        if scope in ("all", "intra"):
            self.intra_fabric.add_window(start, duration, bw_scale=bw_scale,
                                         extra_latency=extra_latency)
        if scope in ("all", "inter"):
            self.inter_fabric.add_window(start, duration, bw_scale=bw_scale,
                                         extra_latency=extra_latency)

    def fabric_change_points(self) -> List[float]:
        return sorted(set(self.intra_fabric.change_points())
                      | set(self.inter_fabric.change_points()))

    def allreduce_time(self, payload_bytes: float,
                       nodes: Sequence[NodeProfile], *,
                       now: float = 0.0) -> float:
        if len(nodes) <= 1:
            return 0.0
        groups: Dict[int, List[NodeProfile]] = {}
        for n in nodes:
            groups.setdefault(self.pod_of(n.name), []).append(n)
        iscale, iextra = self.intra_fabric.at(now)
        xscale, xextra = self.inter_fabric.at(now)
        # each pod's ring is bottlenecked by its own worst member, not
        # the worst link in the whole participant set
        hier = hierarchical_allreduce_time(
            payload_bytes, [len(g) for g in groups.values()],
            [min(n.link_bw for n in g) * iscale for g in groups.values()],
            self.inter_bw * xscale,
            intra_latency=[max(n.link_latency for n in g) + iextra
                           for g in groups.values()],
            inter_latency=self.inter_latency + xextra)
        if len(groups) == 1:
            return hier
        # a lopsided split (smallest pod sets the cross-phase shard
        # granularity) can make the two-level schedule lose to a plain
        # ring threaded through the topology; route the cheaper one
        flat = ring_allreduce_time(
            payload_bytes, len(nodes),
            min(min(n.link_bw for n in nodes) * iscale,
                self.inter_bw * xscale),
            max(max(n.link_latency for n in nodes) + iextra,
                self.inter_latency + xextra))
        return min(hier, flat)

    def point_to_point_time(self, payload_bytes: float, src: NodeProfile,
                            dst: NodeProfile, *, now: float = 0.0) -> float:
        """One-directional transfer; a cross-pod hop is additionally
        bottlenecked by the inter-pod link and pays its latency."""
        iscale, iextra = self.intra_fabric.at(now)
        bw = min(src.link_bw, dst.link_bw) * iscale
        lat = max(src.link_latency, dst.link_latency) + iextra
        if self.pod_of(src.name) != self.pod_of(dst.name):
            xscale, xextra = self.inter_fabric.at(now)
            bw = min(bw, self.inter_bw * xscale)
            lat += self.inter_latency + xextra
        if bw <= 0.0:
            raise ValueError(
                f"non-positive effective bandwidth {bw!r} between "
                f"{src.name!r} and {dst.name!r}; check link_bw / bw_scale")
        return lat + payload_bytes / bw


__all__ = ["FABRIC_SCOPES", "FabricSchedule", "FabricWindow",
           "NetworkModel", "Topology", "TimedCommsMeter",
           "hierarchical_allreduce_time", "ring_allreduce_time",
           "DEFAULT_LATENCY"]
