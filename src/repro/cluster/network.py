"""Network cost models: flat ring and an n-level fabric-domain tree.

Extends the byte accounting of ``repro.core.comms`` into *time*.  Two
models share one interface (``allreduce_time`` / ``point_to_point_time``
/ ``add_fabric_window``, all taking a ``now``):

:class:`NetworkModel`
    The flat model: one ring over all participants, bottlenecked by the
    slowest link.  Kept as the topology-oblivious baseline.
:class:`Topology`
    Nodes grouped into a tree of :class:`FabricDomain`\\ s — rack ->
    pod -> cluster, to any depth.  Leaf domains hold nodes (their links
    are the nodes' own ``link_bw``); each internal domain joins its
    children with explicit per-path bandwidth/latency.  Collectives are
    priced by :func:`~repro.core.comms.hierarchical_allreduce_time`
    (reduce-scatter down the levels, a shard ring across the top
    bottleneck, all-gather back up).  The classic two-level pod scheme
    is the depth-2 special case and prices bit-identically to it.

Every domain carries its *own* time-varying fabric state: a
:class:`FabricSchedule` is a baseline ``bw_scale``/``extra_latency``
plus piecewise-constant :class:`FabricWindow`\\ s, so scenarios can open
bursty congestion windows on one level — or one named domain — without
touching the others.  The cluster runtime re-prices in-flight
collectives *and* join-time point-to-point transfers at every window
edge.
"""
from __future__ import annotations

import copy
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.comms import (CommDomain, TimedCommsMeter,
                              hierarchical_allreduce_time,
                              ring_allreduce_time)
from repro.cluster.node import DEFAULT_LATENCY, NodeProfile

#: fixed scopes for fabric windows; ``Topology`` additionally accepts
#: ``"level:<k>"`` (every domain at height k, 0 = leaves),
#: ``"domain:<name>"`` (one named domain — every path at that level) and
#: ``"edge:<name>"`` (one named domain's *uplink*: only the single path
#: joining that child to its siblings degrades, so one bad cable is
#: priced on traffic through that child and nowhere else).  The flat
#: NetworkModel has a single fabric and treats every valid scope as the
#: wire.
FABRIC_SCOPES = ("all", "intra", "inter")


@dataclass
class FabricWindow:
    """Fabric degradation active inside [start, end): link bandwidth is
    multiplied by ``bw_scale`` and every hop pays ``extra_latency``."""

    start: float
    end: float
    bw_scale: float = 1.0
    extra_latency: float = 0.0


@dataclass
class FabricSchedule:
    """Piecewise-constant time-varying fabric state.

    ``bw_scale``/``extra_latency`` are the always-on baseline;
    overlapping windows compose (scales multiply, latencies add), so a
    congestion burst during a partition degrades the fabric further.
    """

    bw_scale: float = 1.0
    extra_latency: float = 0.0
    windows: List[FabricWindow] = field(default_factory=list)

    def add_window(self, start: float, duration: Optional[float] = None, *,
                   bw_scale: float = 1.0,
                   extra_latency: float = 0.0) -> FabricWindow:
        """Open a window at ``start``; ``duration`` of None (or <= 0)
        means the degradation is permanent."""
        if bw_scale <= 0.0:
            raise ValueError(f"bw_scale must be positive, got {bw_scale}")
        if extra_latency < 0.0:
            raise ValueError(
                f"extra_latency must be >= 0, got {extra_latency}")
        end = (start + duration if duration is not None and duration > 0
               else math.inf)
        w = FabricWindow(start, end, bw_scale, extra_latency)
        self.windows.append(w)
        return w

    def at(self, now: float) -> Tuple[float, float]:
        """(bandwidth scale, extra latency) in effect at time ``now``."""
        scale, extra = self.bw_scale, self.extra_latency
        for w in self.windows:
            if w.start <= now < w.end:
                scale *= w.bw_scale
                extra += w.extra_latency
        return scale, extra

    def change_points(self) -> List[float]:
        """Finite window edges, sorted — the instants pricing changes."""
        pts = {w.start for w in self.windows}
        pts |= {w.end for w in self.windows if math.isfinite(w.end)}
        return sorted(pts)


def _asym(s: "FabricSchedule") -> bool:
    """True when a schedule can deviate from the identity — the
    structural guard keeping uplink-free topologies bit-identical to
    the pre-uplink pricing."""
    return bool(s.windows) or s.bw_scale != 1.0 or s.extra_latency != 0.0


def _check_scope(scope: str) -> None:
    if scope in FABRIC_SCOPES:
        return
    if (scope.startswith("level:") or scope.startswith("domain:")
            or scope.startswith("edge:")):
        return
    raise ValueError(f"scope must be one of {FABRIC_SCOPES} or "
                     f"'level:<k>' / 'domain:<name>' / 'edge:<name>', "
                     f"got {scope!r}")


@dataclass
class FabricDomain:
    """One domain in the fabric level tree.

    A *leaf* domain lists the node names it contains; its links are the
    nodes' own ``link_bw``/``link_latency``, so it needs no bandwidth of
    its own.  An *internal* domain joins its ``children`` with per-path
    bandwidth ``bw`` (one child's route to its peers at this level, not
    an aggregate pipe) and per-hop ``latency``.  Every domain carries
    its own :class:`FabricSchedule`: a congestion window on a pod's
    domain squeezes only the links joining that pod's racks, a window on
    the root squeezes only the paths joining pods.

    ``uplink`` is the schedule on THIS domain's single path up into its
    parent's level (``scope="edge:<name>"``): where ``fabric`` on the
    parent degrades every sibling path symmetrically, a window on one
    child's uplink prices only collectives and transfers whose route
    actually crosses that child's edge — the per-path fabric-asymmetry
    model.  Empty on the root (it has no parent edge).
    """

    name: str
    bw: Optional[float] = None
    latency: float = 0.0
    children: List["FabricDomain"] = field(default_factory=list)
    nodes: List[str] = field(default_factory=list)
    fabric: FabricSchedule = field(default_factory=FabricSchedule)
    uplink: FabricSchedule = field(default_factory=FabricSchedule)


@dataclass
class NetworkModel:
    """Flat cost model: every collective is one ring over the global
    min-bandwidth link.

    ``bw_scale``/``extra_latency`` seed the baseline of the fabric
    schedule (kept as constructor arguments for compatibility);
    scenarios add time-windowed degradations on top via
    :meth:`add_fabric_window`.
    """

    bw_scale: float = 1.0
    extra_latency: float = 0.0
    fabric: Optional[FabricSchedule] = None

    def __post_init__(self) -> None:
        if self.fabric is None:
            self.fabric = FabricSchedule(bw_scale=self.bw_scale,
                                         extra_latency=self.extra_latency)
        elif self.bw_scale != 1.0 or self.extra_latency != 0.0:
            raise ValueError(
                "pass the baseline via the FabricSchedule, not both a "
                "fabric and bw_scale/extra_latency")

    def add_fabric_window(self, start: float,
                          duration: Optional[float] = None, *,
                          bw_scale: float = 1.0, extra_latency: float = 0.0,
                          scope: str = "all") -> None:
        _check_scope(scope)          # flat fabric: every scope is the wire
        self.fabric.add_window(start, duration, bw_scale=bw_scale,
                               extra_latency=extra_latency)

    def fabric_change_points(self) -> List[float]:
        return self.fabric.change_points()

    def allreduce_time(self, payload_bytes: float,
                       nodes: Sequence[NodeProfile], *,
                       now: float = 0.0) -> float:
        p = len(nodes)
        if p <= 1:
            return 0.0
        scale, extra = self.fabric.at(now)
        bw = min(n.link_bw for n in nodes) * scale
        if bw <= 0.0:
            raise ValueError(
                f"non-positive effective bandwidth {bw!r} among "
                f"{[n.name for n in nodes]}; check link_bw / bw_scale")
        lat = max(n.link_latency for n in nodes) + extra
        return ring_allreduce_time(payload_bytes, p, bw, lat)

    def point_to_point_time(self, payload_bytes: float, src: NodeProfile,
                            dst: NodeProfile, *, now: float = 0.0) -> float:
        """One-directional transfer (elastic join: shipping params to a
        fresh trainer)."""
        scale, extra = self.fabric.at(now)
        bw = min(src.link_bw, dst.link_bw) * scale
        if bw <= 0.0:
            raise ValueError(
                f"non-positive effective bandwidth {bw!r} between "
                f"{src.name!r} and {dst.name!r}; check link_bw / bw_scale")
        lat = max(src.link_latency, dst.link_latency) + extra
        return lat + payload_bytes / bw


@dataclass
class Topology:
    """N-level fabric: a tree of :class:`FabricDomain`\\ s.

    Construct either from the classic two-level pod spelling — ``pods``
    lists node *names* per pod, joined by cross-pod paths of ``inter_bw``
    each — or from an explicit ``tree`` (see :meth:`from_profiles` for
    the rack/pod/cluster builder).  Collectives are routed reduce-scatter
    down the levels -> shard ring across the top -> all-gather up, which
    reduces to the plain ring whenever all participants share a leaf
    domain.  Because the smallest sibling group sets the cross-phase
    shard granularity, a lopsided split can lose to a plain ring
    threaded through the same fabric; :meth:`allreduce_time` routes the
    cheaper of the two.

    Every domain has its own time-varying :class:`FabricSchedule`;
    :meth:`add_fabric_window` scopes a degradation to all links
    (``"all"``), the leaf level (``"intra"``), every internal level
    (``"inter"``), one level (``"level:<k>"``, 0 = leaves), or one named
    domain (``"domain:<name>"``).  ``intra_fabric``/``inter_fabric``
    keep the two-level spelling working: when given, all leaf (resp.
    internal) domains share that schedule object.
    """

    pods: Optional[List[List[str]]] = None
    inter_bw: Optional[float] = None
    inter_latency: float = DEFAULT_LATENCY
    intra_fabric: Optional[FabricSchedule] = None
    inter_fabric: Optional[FabricSchedule] = None
    tree: Optional[FabricDomain] = None

    def __post_init__(self) -> None:
        if self.tree is None:
            if self.pods is None or self.inter_bw is None:
                raise ValueError("Topology needs either a tree or "
                                 "pods + inter_bw")
            if self.inter_bw <= 0.0:
                raise ValueError(f"inter_bw must be positive, got "
                                 f"{self.inter_bw}")
            leaves = [
                FabricDomain(
                    name=f"p{i}", nodes=list(pod),
                    fabric=(self.intra_fabric if self.intra_fabric
                            is not None else FabricSchedule()))
                for i, pod in enumerate(self.pods)]
            self.tree = FabricDomain(
                name="cluster", bw=self.inter_bw,
                latency=self.inter_latency, children=leaves,
                fabric=(self.inter_fabric if self.inter_fabric is not None
                        else FabricSchedule()))
        self._reindex()

    # ------------------------------------------------------------ index
    def _reindex(self) -> None:
        self._domains: List[FabricDomain] = []
        self._by_name: Dict[str, FabricDomain] = {}
        self._parent: Dict[int, Optional[FabricDomain]] = {}
        self._height: Dict[int, int] = {}
        self._leaf_of: Dict[str, FabricDomain] = {}
        self._pod_of: Dict[str, int] = {}

        def walk(dom: FabricDomain, parent: Optional[FabricDomain],
                 top: int) -> int:
            if dom.nodes and dom.children:
                raise ValueError(f"domain {dom.name!r} is both a leaf "
                                 f"(nodes) and a parent (children)")
            if dom.name in self._by_name:
                raise ValueError(f"domain name {dom.name!r} appears more "
                                 f"than once in the tree")
            self._domains.append(dom)
            self._by_name[dom.name] = dom
            self._parent[id(dom)] = parent
            if dom.children:
                if dom.bw is None or dom.bw <= 0.0:
                    raise ValueError(
                        f"internal domain {dom.name!r} needs a positive "
                        f"bw, got {dom.bw}")
                h = 1 + max(walk(c, dom, i if parent is None else top)
                            for i, c in enumerate(dom.children))
            else:
                for n in dom.nodes:
                    if n in self._leaf_of:
                        raise ValueError(f"node {n!r} appears in more "
                                         f"than one domain")
                    self._leaf_of[n] = dom
                    self._pod_of[n] = top
                h = 0
            self._height[id(dom)] = h
            return h

        walk(self.tree, None, 0)
        # derived two-level view: node names under each top-level child
        def names(dom: FabricDomain) -> List[str]:
            if not dom.children:
                return list(dom.nodes)
            return [n for c in dom.children for n in names(c)]
        self.pods = ([names(c) for c in self.tree.children]
                     if self.tree.children else [names(self.tree)])

    def __deepcopy__(self, memo) -> "Topology":
        new = object.__new__(Topology)
        memo[id(self)] = new
        new.inter_bw = self.inter_bw
        new.inter_latency = self.inter_latency
        new.intra_fabric = copy.deepcopy(self.intra_fabric, memo)
        new.inter_fabric = copy.deepcopy(self.inter_fabric, memo)
        new.tree = copy.deepcopy(self.tree, memo)
        new._reindex()               # recomputes pods from the copied tree
        return new

    @classmethod
    def from_profiles(cls, profiles: Sequence[NodeProfile], *,
                      inter_bw: float,
                      inter_latency: float = DEFAULT_LATENCY,
                      pod_bw: Optional[float] = None,
                      pod_latency: float = DEFAULT_LATENCY) -> "Topology":
        """Build the tree from profile attributes.

        Without ``pod_bw``: the two-level scheme — profiles group by
        their ``pod`` attribute (None -> pod 0) into leaf domains joined
        by cross-pod paths of ``inter_bw``.  With ``pod_bw``: three
        levels — profiles group by ``(pod, rack)`` (None -> 0) into rack
        leaf domains named ``p<i>r<j>``, racks join inside pod domains
        ``p<i>`` over paths of ``pod_bw``/``pod_latency``, and pods join
        at the ``cluster`` root over ``inter_bw``/``inter_latency``.
        """
        if pod_bw is None:
            pods: Dict[int, List[str]] = {}
            for p in profiles:
                pods.setdefault(p.pod if p.pod is not None else 0,
                                []).append(p.name)
            return cls(pods=[pods[k] for k in sorted(pods)],
                       inter_bw=inter_bw, inter_latency=inter_latency)
        grouped: Dict[int, Dict[int, List[str]]] = {}
        for p in profiles:
            pi = p.pod if p.pod is not None else 0
            ri = p.rack if p.rack is not None else 0
            grouped.setdefault(pi, {}).setdefault(ri, []).append(p.name)
        pods_doms = [
            FabricDomain(
                name=f"p{pi}", bw=pod_bw, latency=pod_latency,
                children=[FabricDomain(name=f"p{pi}r{ri}",
                                       nodes=grouped[pi][ri])
                          for ri in sorted(grouped[pi])])
            for pi in sorted(grouped)]
        return cls(tree=FabricDomain(name="cluster", bw=inter_bw,
                                     latency=inter_latency,
                                     children=pods_doms))

    # ----------------------------------------------------------- lookup
    def _leaf(self, name: str) -> FabricDomain:
        try:
            return self._leaf_of[name]
        except KeyError:
            raise ValueError(f"node {name!r} is not in the topology "
                             f"(known: {sorted(self._leaf_of)})") from None

    def pod_of(self, name: str) -> int:
        """Index of the top-level domain containing ``name`` (the pod
        index under the two-level spelling)."""
        self._leaf(name)
        return self._pod_of[name]

    def domain_names(self) -> List[str]:
        return [d.name for d in self._domains]

    # ----------------------------------------------------------- fabric
    def _scope_domains(self, scope: str) -> List[FabricDomain]:
        _check_scope(scope)
        if scope == "all":
            return list(self._domains)
        if scope == "intra":
            return [d for d in self._domains if not d.children]
        if scope == "inter":
            return [d for d in self._domains if d.children]
        if scope.startswith("level:"):
            try:
                k = int(scope.split(":", 1)[1])
            except ValueError:
                raise ValueError(f"bad level scope {scope!r}") from None
            doms = [d for d in self._domains if self._height[id(d)] == k]
            if not doms:
                raise ValueError(
                    f"no domains at level {k} (tree height "
                    f"{self._height[id(self.tree)]})")
            return doms
        name = scope.split(":", 1)[1]
        if name not in self._by_name:
            raise ValueError(f"unknown domain {name!r} (known: "
                             f"{self.domain_names()})")
        return [self._by_name[name]]

    def add_fabric_window(self, start: float,
                          duration: Optional[float] = None, *,
                          bw_scale: float = 1.0, extra_latency: float = 0.0,
                          scope: str = "all") -> None:
        if scope.startswith("edge:"):
            # per-path asymmetry: the window lands on one child's
            # uplink schedule, so only routes crossing that edge pay
            name = scope.split(":", 1)[1]
            if name not in self._by_name:
                raise ValueError(f"unknown domain {name!r} (known: "
                                 f"{self.domain_names()})")
            dom = self._by_name[name]
            if self._parent[id(dom)] is None:
                raise ValueError(f"domain {name!r} is the root and has "
                                 f"no uplink edge")
            dom.uplink.add_window(start, duration, bw_scale=bw_scale,
                                  extra_latency=extra_latency)
            return
        # domains may share a schedule object (the two-level spelling
        # shares one across all pods): dedupe so a window lands once
        scheds = {id(d.fabric): d.fabric
                  for d in self._scope_domains(scope)}
        for f in scheds.values():
            f.add_window(start, duration, bw_scale=bw_scale,
                         extra_latency=extra_latency)

    def fabric_change_points(self) -> List[float]:
        pts: set = set()
        scheds = {id(s): s for d in self._domains
                  for s in (d.fabric, d.uplink)}
        for f in scheds.values():
            pts |= set(f.change_points())
        return sorted(pts)

    def participant_tree(self, names: Sequence[str]):
        """Participant-pruned domain tree as nested lists of node names
        (a leaf group is a flat name list, in caller order) — the same
        pruning :meth:`allreduce_time` prices: empty domains drop,
        single-child levels collapse.  Execution backends map this onto
        nested process groups so a real hierarchical all-reduce runs
        where the tree says it should."""
        doms = {id(self._leaf(nm)) for nm in names}

        def build(dom: FabricDomain):
            if not dom.children:
                if id(dom) not in doms:
                    return None
                return [nm for nm in names if self._leaf_of[nm] is dom]
            kids = [k for k in (build(c) for c in dom.children)
                    if k is not None]
            if not kids:
                return None
            if len(kids) == 1:
                return kids[0]
            return kids

        return build(self.tree)

    # ---------------------------------------------------------- pricing
    def allreduce_time(self, payload_bytes: float,
                       nodes: Sequence[NodeProfile], *,
                       now: float = 0.0) -> float:
        if len(nodes) <= 1:
            return 0.0
        members: Dict[int, List[NodeProfile]] = {}
        for n in nodes:
            members.setdefault(id(self._leaf(n.name)), []).append(n)
        # effective per-level links of the participant-pruned tree; the
        # same walk collects the bottleneck set for the flat fallback
        path_bws: List[float] = []
        path_lats: List[float] = []

        def build(dom: FabricDomain) -> Optional[CommDomain]:
            if not dom.children:
                g = members.get(id(dom))
                if not g:
                    return None
                scale, extra = dom.fabric.at(now)
                bw = min(n.link_bw for n in g) * scale
                if bw <= 0.0:
                    raise ValueError(
                        f"non-positive effective intra_bw {bw!r} in domain "
                        f"{dom.name!r} among {[n.name for n in g]}; check "
                        f"link_bw / bw_scale")
                lat = max(n.link_latency for n in g) + extra
                path_bws.append(bw)
                path_lats.append(lat)
                return CommDomain(bw=bw, latency=lat, size=len(g))
            pairs = [(c, k) for c, k in ((c, build(c))
                                         for c in dom.children)
                     if k is not None]
            if not pairs:
                return None
            if len(pairs) == 1:      # level not crossed: prices nothing
                return pairs[0][1]
            scale, extra = dom.fabric.at(now)
            bw = dom.bw * scale
            lat = dom.latency + extra
            ups = [c.uplink for c, _ in pairs]
            if any(_asym(u) for u in ups):
                # per-path asymmetry: the exchange at this level is
                # bottlenecked by the slowest participating child's
                # uplink; non-participating siblings' edges price
                # nothing.  Structurally guarded so the symmetric case
                # stays bit-identical to the uplink-free model.
                states = [u.at(now) for u in ups]
                bw *= min(s for s, _ in states)
                lat += max(e for _, e in states)
            if bw <= 0.0:
                raise ValueError(
                    f"non-positive effective bandwidth {bw!r} on domain "
                    f"{dom.name!r}; check bw / bw_scale")
            path_bws.append(bw)
            path_lats.append(lat)
            return CommDomain(bw=bw, latency=lat,
                              children=tuple(k for _, k in pairs))

        spec = build(self.tree)
        hier = hierarchical_allreduce_time(payload_bytes, spec)
        if not spec.children:
            return hier
        # the smallest sibling group sets the cross-phase shard
        # granularity, so a lopsided split can make the level schedule
        # lose to a plain ring threaded through the same fabric — route
        # the cheaper one
        flat = ring_allreduce_time(payload_bytes, len(nodes),
                                   min(path_bws), max(path_lats))
        return min(hier, flat)

    def _path(self, a: FabricDomain, b: FabricDomain) -> List[FabricDomain]:
        """Internal domains crossed between two leaves: each side's
        ancestors up to and including the lowest common one."""
        up_a: List[FabricDomain] = []
        d = self._parent[id(a)]
        while d is not None:
            up_a.append(d)
            d = self._parent[id(d)]
        idx = {id(x): i for i, x in enumerate(up_a)}
        up_b: List[FabricDomain] = []
        d = self._parent[id(b)]
        while d is not None and id(d) not in idx:
            up_b.append(d)
            d = self._parent[id(d)]
        if d is None:
            raise ValueError(f"domains {a.name!r} and {b.name!r} share no "
                             f"ancestor")
        return up_a[:idx[id(d)] + 1] + up_b

    def _edges(self, a: FabricDomain, b: FabricDomain
               ) -> List[FabricDomain]:
        """Child domains whose uplink edge an a->b route crosses: each
        side's chain from the leaf up to (excluding) the lowest common
        ancestor."""
        up_a: List[FabricDomain] = [a]
        d = self._parent[id(a)]
        while d is not None:
            up_a.append(d)
            d = self._parent[id(d)]
        idx = {id(x): i for i, x in enumerate(up_a)}
        up_b: List[FabricDomain] = [b]
        d = self._parent[id(b)]
        while d is not None and id(d) not in idx:
            up_b.append(d)
            d = self._parent[id(d)]
        return up_a[:idx[id(d)]] + up_b

    def point_to_point_time(self, payload_bytes: float, src: NodeProfile,
                            dst: NodeProfile, *, now: float = 0.0) -> float:
        """One-directional transfer (elastic join): bottlenecked by both
        endpoints' links and every internal level crossed between their
        leaf domains, each of which also adds its per-hop latency."""
        ls, ld = self._leaf(src.name), self._leaf(dst.name)
        sscale, sextra = ls.fabric.at(now)
        if ls is ld:
            bw = min(src.link_bw, dst.link_bw) * sscale
            lat = max(src.link_latency, dst.link_latency) + sextra
        else:
            dscale, dextra = ld.fabric.at(now)
            bw = min(src.link_bw * sscale, dst.link_bw * dscale)
            lat = max(src.link_latency + sextra, dst.link_latency + dextra)
            for dom in self._path(ls, ld):
                scale, extra = dom.fabric.at(now)
                bw = min(bw, dom.bw * scale)
                lat += dom.latency + extra
            for edge in self._edges(ls, ld):
                # a degraded uplink squeezes only routes crossing that
                # child's single edge into its parent level (the edge
                # rides the parent's per-path bw, further scaled)
                if not _asym(edge.uplink):
                    continue
                par = self._parent[id(edge)]
                us, ue = edge.uplink.at(now)
                ps, _pe = par.fabric.at(now)
                bw = min(bw, par.bw * ps * us)
                lat += ue
        if bw <= 0.0:
            raise ValueError(
                f"non-positive effective bandwidth {bw!r} between "
                f"{src.name!r} and {dst.name!r}; check link_bw / bw_scale")
        return lat + payload_bytes / bw


__all__ = ["FABRIC_SCOPES", "CommDomain", "FabricDomain", "FabricSchedule",
           "FabricWindow", "NetworkModel", "Topology", "TimedCommsMeter",
           "hierarchical_allreduce_time", "ring_allreduce_time",
           "DEFAULT_LATENCY"]
