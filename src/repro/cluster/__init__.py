"""repro.cluster — event-driven virtual-cluster runtime for AdLoCo.

Runs real AdLoCo numerics (the same jitted ``TrainerRound`` primitives
as ``repro.core.adloco``) over *simulated* heterogeneous nodes, so the
paper's dynamic-workload scenarios — stragglers, slow links, trainers
joining and leaving — can be exercised and timed without a physical
cluster.

Quick start::

    from repro.cluster import (ClusterEvent, NetworkModel, run_cluster,
                               make_heterogeneous_profiles)

    profiles = make_heterogeneous_profiles(k * M, ratio=4.0, jitter=0.1)
    pool, hist, report = run_cluster(loss_fn, inits, streams, acfg,
                                     policy="async", profiles=profiles,
                                     eval_fn=eval_fn)
    # hist.sim_time x hist.eval_loss -> time-to-target under the sim clock

Which sync policy should I use?
-------------------------------
``sync``
    Barrier semantics identical to the legacy ``train_adloco`` loop.
    Use it as the ground-truth baseline: with merging disabled the
    parameter trajectory is bit-identical to the host loop, so any
    simulated-time comparison is apples-to-apples.  Pick it when the
    network is fast relative to a round (comm « compute) or when you
    need exactly reproducible numerics.
``async``
    ACCO-style overlap: workers keep accumulating inner steps while the
    outer all-reduce is in flight; the delayed pseudo-gradient applies
    on arrival and workers rebase, keeping in-flight progress.  Pick it
    when outer syncs are expensive — slow/lossy links, large models,
    high heterogeneity (the slowest node's link bottlenecks the ring).
    Expect a small loss-trajectory perturbation (one round of delay) in
    exchange for hiding comm time entirely.
``elastic``
    ``async`` plus scripted :class:`ClusterEvent`s — trainers leave
    (folded into the pool via ``mit.do_merge``) and join (cloned from
    the most-advanced trainer onto spare nodes/streams).  Pick it to
    study preemptible/spot capacity and pool-size dynamics; pass extra
    streams and profiles beyond k*M to give joiners somewhere to land.

``benchmarks/cluster_bench.py`` compares the three under 1x/2x/4x node
heterogeneity; ``examples/heterogeneous_cluster.py`` is the narrated
tour.
"""
from repro.cluster.network import NetworkModel
from repro.cluster.node import (NodeProfile, Slowdown,
                                make_heterogeneous_profiles)
from repro.cluster.runtime import (POLICIES, ClusterEvent, ClusterReport,
                                   run_cluster)

__all__ = [
    "POLICIES", "ClusterEvent", "ClusterReport", "NetworkModel",
    "NodeProfile", "Slowdown", "make_heterogeneous_profiles",
    "run_cluster",
]
