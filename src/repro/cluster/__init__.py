"""repro.cluster — event-driven virtual-cluster runtime for AdLoCo.

Runs real AdLoCo numerics (the same jitted ``TrainerRound`` primitives
as ``repro.core.adloco``) over *simulated* heterogeneous nodes, so the
paper's dynamic-workload scenarios — stragglers, congested fabrics,
pod partitions, trainers joining and leaving — can be exercised and
timed without a physical cluster.  The network model and the scenario
change the simulated clock, never the numerics.

Quick start::

    from repro.cluster import (Topology, make_pod_profiles, run_cluster)

    profiles = make_pod_profiles([4, 4], ratio=2.0)     # 2 pods, 8 nodes
    topo = Topology.from_profiles(profiles, inter_bw=1e5)
    pool, hist, report = run_cluster(loss_fn, inits, streams, acfg,
                                     policy="async", profiles=profiles,
                                     network=topo, eval_fn=eval_fn,
                                     scenario="bursty_congestion")
    # hist.sim_time x hist.eval_loss -> time-to-target under the sim clock

Network models
--------------
``NetworkModel``
    The flat baseline: every collective is one ring over the global
    min-bandwidth link.
``Topology``
    Nodes grouped into pods (by ``NodeProfile.pod`` via
    ``Topology.from_profiles``, or explicit name lists): intra-pod
    traffic rides the node links, cross-pod traffic rides explicit
    bottleneck paths of ``inter_bw`` each, and collectives spanning
    pods are priced by ``core.comms.hierarchical_allreduce_time``
    (per-pod reduce-scatter, concurrent cross-pod shard rings, per-pod
    all-gather).

Both carry time-varying fabric state (``FabricSchedule``): scenarios
open ``FabricWindow``\\ s — bandwidth scaled by ``bw_scale``, hops
paying ``extra_latency`` — and the runtime re-prices in-flight
collectives at every window edge.

Scenario registry
-----------------
``repro.cluster.scenarios`` holds named, deterministic generators that
compile to ``ClusterEvent`` streams; ``run_cluster(scenario="<name>")``
accepts them directly, so benchmarks and the golden-trace tests in
``tests/test_scenarios.py`` exercise identical event streams.
Registered: ``baseline`` (no events), ``bursty_congestion`` (periodic
cross-pod congestion windows: ``start``/``period``/``burst``/``depth``/
``extra_latency``/``count``/``scope``), ``spot_churn`` (seeded Poisson
leave events each followed by a rejoin: ``seed``/``rate``/``horizon``/
``rejoin_after``/``start``), ``pod_partition`` (cross-pod links drop to
``residual`` bandwidth for ``duration`` seconds), and
``flash_crowd_join`` (``joins`` trainers landing every ``spacing``
seconds).  See the generator docstrings for knob semantics; register
new ones with ``scenarios.register_scenario``.

Which sync policy should I use?
-------------------------------
``sync``
    Barrier semantics identical to the legacy ``train_adloco`` loop.
    Use it as the ground-truth baseline: with merging disabled the
    parameter trajectory is bit-identical to the host loop — under any
    topology or fabric schedule — so any simulated-time comparison is
    apples-to-apples.  Pick it when the network is fast relative to a
    round (comm « compute) or when you need exactly reproducible
    numerics.
``async``
    ACCO-style overlap: workers keep accumulating inner steps while the
    outer all-reduce is in flight; the delayed pseudo-gradient applies
    on arrival and workers rebase, keeping in-flight progress.  Pick it
    when outer syncs are expensive — congested or partitioned fabrics,
    slow cross-pod bottlenecks, large models, high heterogeneity.
    Expect a small loss-trajectory perturbation (one round of delay) in
    exchange for hiding comm time entirely.
``elastic``
    ``async`` plus scripted :class:`ClusterEvent`\\ s — trainers leave
    (folded into the pool via ``mit.do_merge``) and join (cloned from
    the most-advanced trainer onto spare nodes/streams).  Pick it to
    study preemptible/spot capacity and pool-size dynamics; pass extra
    streams and profiles beyond k*M to give joiners somewhere to land.

``benchmarks/cluster_bench.py`` compares sync/async under 1x/2x/4x node
heterogeneity and across registered scenarios on a 2-pod topology;
``examples/heterogeneous_cluster.py`` is the narrated tour.
"""
from repro.cluster.network import (FABRIC_SCOPES, FabricSchedule,
                                   FabricWindow, NetworkModel, Topology)
from repro.cluster.node import (NodeProfile, Slowdown, interleave_pods,
                                make_heterogeneous_profiles,
                                make_pod_profiles)
from repro.cluster.runtime import (POLICIES, ClusterEvent, ClusterReport,
                                   run_cluster)
from repro.cluster.scenarios import (SCENARIOS, build_scenario,
                                     list_scenarios, register_scenario)

__all__ = [
    "FABRIC_SCOPES", "POLICIES", "SCENARIOS", "ClusterEvent",
    "ClusterReport", "FabricSchedule", "FabricWindow", "NetworkModel",
    "NodeProfile", "Slowdown", "Topology", "build_scenario",
    "interleave_pods", "list_scenarios", "make_heterogeneous_profiles",
    "make_pod_profiles", "register_scenario", "run_cluster",
]
