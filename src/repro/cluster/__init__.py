"""repro.cluster — event-driven cluster runtime for AdLoCo, with
pluggable execution backends.

Runs real AdLoCo numerics (the same jitted ``TrainerRound`` primitives
as ``repro.core.adloco``) over heterogeneous nodes, so the paper's
dynamic-workload scenarios — stragglers, congested fabrics, flapping
racks, pod partitions, trainers joining and leaving — can be exercised
and timed.  The division of labor:

* a network model (``NetworkModel`` / ``Topology``) describes **where**
  a collective runs — which fabric domains it crosses and what each
  level's paths cost on the simulated clock;
* an execution backend (``repro.cluster.backend``) supplies **how** it
  executes — in-process arithmetic (``SimBackend``, the default) or
  real multi-process ``jax.lax`` collectives over ``jax.distributed``
  (``JaxProcessBackend``, one OS process per worker, launched by
  ``repro.cluster.launch_mp``);
* the scenario decides **what happens** while it runs.

None of the three may change the numerics: the sync policy is
bit-identical to the legacy host loop under every network model and
backend (CI's ``multiprocess-smoke`` lane pins sim/real parity on every
push).

Quick start::

    from repro.cluster import (Topology, make_rack_profiles, run_cluster)

    # 3-level fabric: 2 pods x 2 racks x 2 nodes
    profiles = make_rack_profiles([[2, 2], [2, 2]], ratio=2.0)
    topo = Topology.from_profiles(profiles, inter_bw=1e5, pod_bw=1.5e5)
    pool, hist, report = run_cluster(loss_fn, inits, streams, acfg,
                                     policy="async", profiles=profiles,
                                     network=topo, eval_fn=eval_fn,
                                     scenario="correlated_pod_failure")
    # hist.sim_time x hist.eval_loss -> time-to-target under the sim clock

Execution backends
------------------
``SimBackend``
    Prices every collective analytically (``comms.
    hierarchical_allreduce_time`` under a ``Topology``, the flat ring
    otherwise) and executes the outer reduction as the in-process
    ``jnp.stack`` it always was.  The default when ``run_cluster`` gets
    a ``network=``; bit-identical to the pre-backend runtime (the
    golden-trace digests pin it).
``JaxProcessBackend``
    One process per worker via ``jax.distributed.initialize`` (gloo CPU
    collectives locally; the same code path NCCL/ICI deployments use).
    Every process runs the identical deterministic event loop, computes
    only its own worker's inner steps, and the outer all-reduce executes
    as a real ``jax.lax.pmean`` — with the pricing ``Topology``'s
    participant-pruned ``FabricDomain`` tree mapped onto nested mesh
    axes, so the reduction lowers to grouped collectives per fabric
    level, exactly where the tree says the hierarchical schedule runs.
    The simulated clock still comes from the network model (reports
    stay comparable across backends); wall-clock measured inside each
    real collective lands in ``ClusterReport.real_comm_time``, per
    event in the comms log (``real_s``), and — when tracing — as
    ``real``-clock spans laid alongside the sim spans.  Scope:
    sync/async policies with ``k >= 1`` trainer groups — each group's
    outer sync is a grouped mean over its own ranks and MIT merges
    execute as real cross-group collectives (see *Three-stage method on
    real collectives* below); elastic join/leave scenarios and the
    autoscaler still need in-process pool surgery and stay
    simulator-only.

The dispatch/handle contract (nonblocking collectives)
------------------------------------------------------
Backends expose the outer sync as a split pair, and the runtime drives
it at two different simulated instants:

* ``dispatch_outer(worker_params, stats_vec=None) -> handle`` is called
  at the collective's *launch* point.  It must start the reduction and
  return without waiting for the result: ``JaxProcessBackend`` enqueues
  the jitted ``pmean`` chain via JAX async dispatch (no
  ``block_until_ready``), so the wire works while the caller keeps
  computing; ``SimBackend`` evaluates eagerly (pure in-process
  arithmetic — the handle just carries the finished result).
* ``wait_outer(handle) -> (stacked, stats_total_or_None)`` is called at
  the collective's *arrival* (the priced completion event).  It blocks
  until the result is ready, records the true in-flight window
  (dispatch -> ready) as a ``real``-clock span, and hands back the
  reduced params plus the SUM-reduced phase-1 stats vector when one was
  fused in.

Dispatch order is part of the lockstep contract: every process reaches
every ``dispatch_outer`` in the same order with the same shapes
(first-time shapes warm up with a blocking lockstep execution).  Under
``policy="async"`` the runtime dispatches round ``r``'s outer sync and
immediately starts round ``r+1``'s inner steps — the overlap is now a
measured wall-clock fact (``Trace.overlap_fraction(clock="real")``),
not just the simulated schedule's claim.  Handles may be abandoned
without ``wait_outer`` only where preemption can cancel a trainer
mid-flight, which the simulator-only policies are the only ones to
allow — sim handles are plain data and safe to drop.

Piggybacked stats (payload layout)
---------------------------------
Under ``policy="async"`` with ``acfg.adaptive=True`` the runtime does
not pay a standalone gradient-order stats collective: the phase-1
``[colsum, count]`` vector (``n + 1`` floats for an ``n``-param model)
rides the next outer dispatch as ONE fused collective — traced and
priced as kind ``"piggyback"`` with ``payload_bytes = params_bytes +
stats_payload_bytes``, counted in ``num_stats_syncs``.  On
``JaxProcessBackend`` the fused tree is ``{"params": <stacked pytree>,
"stats": <(1, n+1) float32>}`` reduced by the same ``pmean`` chain; the
phase-2 five scalar moments chain onto the same in-flight window: the
dispatch derives the global mean gradient from the enqueued phase-1
buffers without blocking and enqueues the five-moment reduction as a
second collective on the same handle, which the outer wait collects
alongside the params — no standalone fold-time ``stats`` collective
remains on the wire.  The batch decision folds at the
fused collective's arrival — one round stale, exactly the
``BatchPlanProtocol`` semantics every rank already agrees on.
Sync/elastic policies keep the inline gated stats path, preserving
bit-parity with the legacy host loop.

``python -m repro.cluster.launch_mp --procs 2 --rounds 1 --check`` is
the zero-to-parity smoke: it spawns the processes, runs the canonical
quadratic through the real backend, and asserts the final parameters
match the simulator; add ``--adaptive`` for the batch-ramp variant
(trajectory parity included).

Three-stage method on real collectives (multi-trainer MIT)
----------------------------------------------------------
With ``--k K`` the process set splits into ``K`` disjoint trainer
groups: trainer ``t`` owns the contiguous rank block ``[t*M, (t+1)*M)``
where ``M = P / K`` (``validate`` rejects anything that doesn't divide
evenly).  The device mesh grows a leading ``"t"`` axis over the
groups, with the fabric axes nested inside it whenever every group's
participant-pruned ``FabricDomain`` tree has the same shape (one flat
row per group otherwise).  Grouped reductions never name ``"t"``, so
each trainer's outer sync is a ``lax.pmean`` chain over its *own*
block only — ``K`` independent DiLoCo instances sharing one mesh, one
lockstep event loop, and one wire.

MIT merges (and the final consolidate) are the one place groups talk
to each other, and they execute as real cross-group collectives
(``merge_reducer``): each member rank contributes its trainer's
replica scaled by ``weight / M`` (the M group ranks split the group's
share), non-member ranks contribute zeros of the same shape, and a
single global ``psum`` folds both the weighted parameter sum and the
total-weight row; the division yields Algorithm 2's batch-weighted
average replicated on every rank.  The merge is priced on the sim
clock exactly as the ``SimBackend`` prices it (so ``--check`` parity
covers the merged params, the merge applied-events, and the sim-span
trace digest), while the measured wall time lands in
``real_comm_time`` and as a ``real``-clock ``merge`` span.
``merge_drift_window`` gating, survivor bookkeeping and stream unions
stay host-side pool surgery — identical on both backends because it is
pure rank-indexed group-membership arithmetic over the same
deterministic loop.

``validate`` still rejects elastic join/leave scenario events, the
autoscaler, and ``adaptive`` with ``k > 1``: the first two resize the
pool mid-run (cross-process pool surgery — remapping live ranks
between groups — is not built yet), and the stats protocol reduces
over the whole fabric rather than per trainer group, so adaptive
multi-trainer pools would feed every group the union statistics.
``python -m repro.cluster.launch_mp --procs 4 --k 2 --rounds 6
--merge --check`` is the multi-trainer smoke (CI runs it): two
2-process trainers, at least one executed merge, float parity with the
simulator end to end.

Distributed adaptive batching (the stats-reduction protocol)
------------------------------------------------------------
Adaptive batching + switch mode run end-to-end on both backends.  The
coordination problem — per-rank batch statistics would desynchronize
the compiled shapes — is solved by a shape-agreement protocol
(``repro.core.adloco.BatchPlanProtocol`` over ``repro.core.batching.
distributed_stats``): the five sufficient statistics of the batching
tests are *additive* given the global mean gradient, so each rank's
worker contributes its microbatch-mean gradient rows and two
all-reduces — the gradient-sized ``[colsum, count]`` vector, then the
five scalar moments — hand every rank bit-identical ``GradStats``.  The requested batch and the
``ExecutionPlan`` are pure functions of those values and the shared
config, so every rank compiles the same shapes each round without
further coordination.  Under the ``SimBackend`` the reduction is
in-process (bit-identical to the legacy host loop); under the
``JaxProcessBackend`` both phases execute as real ``lax.pmean``\\ s
over the fabric mesh (``stats_estimator="microbatch"`` required — the
per-sample probe is rank-local and stays rejected).  The runtime
prices every stats reduction as a collective over the trainer's nodes
(``ClusterReport.num_stats_syncs``; duration inside ``comm_time``),
re-priced at fabric window edges like any in-flight collective, and
batch growth feeds the per-node roofline compute — so sync, async and
elastic all experience the ramp on the clock, not just in the
numerics.  Async runs fuse phase 1 onto the outer sync (see
*Piggybacked stats* above), so adaptive rounds there pay one
gradient-order collective, not two.

Reporting & tracing
-------------------
Three tiers, cheapest first:

``ClusterReport``
    Aggregate scalars, always on: ``sim_time``, ``comm_time``,
    ``num_syncs``, per-round logs, ``applied_events``.
    ``report.summary()`` is the golden-digest surface — byte-stable
    across PRs; ``report.summary(extended=True)`` adds the opt-in
    fields (``real_comm_time``, ``num_stats_syncs``, and — when the
    run was traced — ``utilization``, ``blocked_frac``, ``idle_frac``,
    ``overlap_frac``) without perturbing the default dict.
``Trace`` (``repro.cluster.trace``)
    The structured tier: ``run_cluster(..., trace=Trace())`` makes the
    event loop record one typed span per inner-compute block, outer
    collective, stats reduction, join transfer and fabric window, plus
    instant annotations (re-pricings, joins, leaves, merges,
    slowdowns).  Strictly opt-in — with the default ``trace=None``
    nothing is allocated and scheduling is untouched (the golden
    digests pin that).  Derived metrics: ``trace.utilization()`` — a
    per-trainer busy / comm-blocked / idle ledger asserted to
    partition each trainer's alive window exactly — and
    ``trace.overlap_fraction()`` — collective in-flight time
    coincident with the same trainer's compute over total collective
    time, the ROADMAP item-1 gate (sync scores exactly 0.0; async > 0
    wherever an outer all-reduce hides behind compute).  On the real
    backend, wall-clock spans measured inside each executed collective
    land in the same trace on a second clock (``launch_mp --trace``).
``trace.to_perfetto()`` / ``repro.cluster.trace_report``
    The export tier: Chrome-trace/Perfetto JSON (load in
    https://ui.perfetto.dev), with exact-seconds endpoints embedded so
    ``Trace.from_perfetto`` round-trips digest-identically.  ``python
    -m repro.cluster.trace_report trace.json`` prints the ledger,
    overlap breakdown and longest spans; ``--validate`` is the CI
    schema gate.  ``cluster_bench`` rows carry ``utilization`` and
    ``overlap_frac`` columns derived the same way.

Network models
--------------
``NetworkModel``
    The flat baseline: every collective is one ring over the global
    min-bandwidth link.
``Topology``
    An n-level tree of ``FabricDomain``\\ s (rack -> pod -> cluster, to
    any depth).  Leaf domains hold nodes — their links are the nodes'
    own ``link_bw`` — and each internal domain joins its children with
    explicit per-path bandwidth/latency.  Collectives are priced by
    ``core.comms.hierarchical_allreduce_time``: ring reduce-scatter
    inside every leaf group, reduce-scatters of the surviving shards up
    the internal levels, a concurrent shard ring across the top
    bottleneck, and the mirror-image all-gathers back down.  Build one
    from the classic two-level spelling (``pods`` + ``inter_bw``; prices
    bit-identically to the old pod-only model), from profile attributes
    (``from_profiles``; pass ``pod_bw`` to get rack/pod/cluster from
    ``NodeProfile.pod``/``.rack``), or hand ``tree=`` an explicit
    ``FabricDomain``.

Every domain carries its own time-varying ``FabricSchedule``: scenarios
open ``FabricWindow``\\ s — bandwidth scaled by ``bw_scale``, hops
paying ``extra_latency`` — scoped to ``"all"``, the leaf level
(``"intra"``), every internal level (``"inter"``), one level
(``"level:<k>"``, 0 = leaves), one named domain
(``"domain:<name>"``), or one named domain's *uplink edge* into its
parent (``"edge:<name>"`` — per-path fabric asymmetry: only
collectives and transfers whose routes cross that edge pay, the
siblings' paths stay nominal), so a window can hit one rack's links
without touching the rest of the fabric.  The runtime re-prices in-flight
collectives *and* join-time parameter transfers at every window edge
(fraction done credited, remainder re-costed).

Scenario registry
-----------------
``repro.cluster.scenarios`` holds named, deterministic generators that
compile to ``ClusterEvent`` streams; ``run_cluster(scenario="<name>")``
accepts them directly, so benchmarks and the golden-trace tests in
``tests/test_scenarios.py`` exercise identical event streams.
Registered: ``baseline`` (no events), ``bursty_congestion`` (periodic
congestion windows), ``spot_churn`` (seeded Poisson leave events each
followed by a rejoin), ``pod_partition`` (cross-pod links drop to
``residual`` bandwidth), ``flash_crowd_join`` (``joins`` trainers
landing every ``spacing`` seconds), and four co-scripted generators
that couple node dynamics with fabric windows:
``correlated_pod_failure`` (a pod's nodes slow down *and* the fabric
joining pods degrades, together), ``diurnal_congestion`` (piecewise-
constant cosine bandwidth schedule), ``rack_flap`` (one named rack
domain's level-0 fabric oscillates) and ``straggler_cascade``
(staggered node slowdowns inside an open congestion window), plus
``drifted_merge`` (one trainer slowed until it drifts past the merge
window, pinning the skip-the-laggard merge semantics).  The
adaptive arms ``adaptive_ramp`` (clean fabric; the ramp lives in the
config) and ``congested_adaptive`` (a deep congestion window colliding
with the middle of the batch ramp) are meant to run with
``acfg.adaptive=True``; ``autoscale_ramp`` (clean fabric, no scripted
events — the pool dynamics come from the autoscale policy) and
``preemption_storm_growth`` (scripted leaves landing mid-ramp, so the
policy must rebuild the pool it just lost) are meant to run with
``autoscale=`` as well.  ``build_scenario`` compiles a name into a
:class:`~repro.cluster.scenarios.Scenario` record — ``(name, knobs,
events)`` — that ``run_cluster`` accepts anywhere a plain event list
works and threads into ``summary(extended=True)["scenario"]``.  See
the generator docstrings for knob semantics; register new ones with
``scenarios.register_scenario``.

Autoscaling
-----------
AdLoCo's batch tests grow the requested global batch roughly
exponentially, so a fixed pool's gradients-per-worker grows with it.
``run_cluster(..., policy="elastic", autoscale=BandAutoscale(...))``
(or ``ClusterSpec(autoscale=...)``) closes the loop the adadamp way: an
:class:`~repro.cluster.autoscale.ElasticPolicy` observes every round
boundary's decided batch and scripts ``join``/``leave`` events through
the same elastic machinery scenarios use — scale-ups pay real
``point_to_point_time`` parameter transfers (re-priced at fabric-window
edges), joiners inherit the source trainer's requested batch, and each
trainer executes only its ``ceil(requested_batch / pool_size)`` share
while the batch *decision* keeps tracking the full requested batch.
Actions land in ``applied_events`` (kind ``"autoscale"``, with the
observed gradients-per-worker), ``ClusterReport.num_autoscale_events``,
and fabric-lane trace instants; joins that exhaust the spare pool
record a ``"join_skipped"`` event instead of failing silently.  The
reference policy :class:`~repro.cluster.autoscale.BandAutoscale` holds
gradients-per-worker inside a ``[lo, hi]`` hysteresis band with a
cooldown between actions.  Pair it with ``acfg.k_correct > 1``
(PadaDamp-style predicted growth: the exact gradient-order stats
reduction runs every ``k_correct`` rounds and the fitted exponential
trajectory fills the rounds between, cutting stats collectives by
~``k_correct``x) to co-scale the fleet against a mostly-predicted
batch trajectory.  ``History.eval_loss_pool`` tracks the
batch-weighted pool-average parameters (what ``consolidate`` would
return) so time-to-target comparisons see the whole fleet, not one
anchor trainer.

Which sync policy should I use?
-------------------------------
``sync``
    Barrier semantics identical to the legacy ``train_adloco`` loop.
    Use it as the ground-truth baseline: with merging disabled the
    parameter trajectory is bit-identical to the host loop — under any
    topology or fabric schedule — so any simulated-time comparison is
    apples-to-apples.  Pick it when the network is fast relative to a
    round (comm « compute) or when you need exactly reproducible
    numerics.
``async``
    ACCO-style overlap: workers keep accumulating inner steps while the
    outer all-reduce is in flight; the delayed pseudo-gradient applies
    on arrival and workers rebase, keeping in-flight progress.  Pick it
    when outer syncs are expensive — congested or partitioned fabrics,
    slow cross-pod bottlenecks, large models, high heterogeneity.
    Expect a small loss-trajectory perturbation (one round of delay) in
    exchange for hiding comm time entirely.  High outer Nesterov
    momentum (0.9) is underdamped under the one-round staleness; set
    ``acfg.delay_compensation=True`` and the outer step scales the
    momentum by the *measured* staleness of each applied
    pseudo-gradient (``mu / (1 + delay)`` — 0.9 behaves like 0.45 at
    the async steady-state delay of one round, and sync runs, at delay
    0, are untouched), so the previously diverging configs converge
    (``tests/test_cluster.py`` pins the regression).
``elastic``
    ``async`` plus scripted :class:`ClusterEvent`\\ s — trainers leave
    (folded into the pool via ``mit.do_merge``) and join (cloned from
    the most-advanced trainer onto spare nodes/streams).  Pick it to
    study preemptible/spot capacity and pool-size dynamics; pass extra
    streams and profiles beyond k*M to give joiners somewhere to land.
    Merges are round-tagged and fire on time: a trainer whose round
    counter has drifted behind the merge round by
    ``acfg.merge_drift_window`` or more is *skipped* (recorded in the
    applied event's ``skipped`` list) rather than stalling the merge
    and folding rounds-stale params into the pool.

``benchmarks/cluster_bench.py`` compares sync/async under 1x/2x/4x node
heterogeneity, across registered scenarios on a 2-pod topology, and
across the co-scripted scenarios on a 3-level rack/pod/cluster fabric;
``examples/heterogeneous_cluster.py`` is the narrated tour.
"""
from repro.cluster.autoscale import BandAutoscale, ElasticPolicy
from repro.cluster.backend import (CollectiveBackend, JaxProcessBackend,
                                   SimBackend)
from repro.cluster.network import (FABRIC_SCOPES, CommDomain, FabricDomain,
                                   FabricSchedule, FabricWindow,
                                   NetworkModel, Topology)
from repro.cluster.node import (NodeProfile, Slowdown, interleave_pods,
                                make_heterogeneous_profiles,
                                make_pod_profiles, make_rack_profiles)
from repro.cluster.runtime import (POLICIES, ClusterEvent, ClusterReport,
                                   ClusterSpec, run_cluster)
from repro.cluster.scenarios import (SCENARIOS, Scenario, build_scenario,
                                     list_scenarios, register_scenario)
from repro.cluster.trace import (Span, Trace, TraceEvent,
                                 validate_perfetto)

__all__ = [
    "FABRIC_SCOPES", "POLICIES", "SCENARIOS", "BandAutoscale",
    "ClusterEvent", "ClusterReport", "ClusterSpec", "CollectiveBackend",
    "CommDomain", "ElasticPolicy", "FabricDomain", "FabricSchedule",
    "FabricWindow", "JaxProcessBackend", "NetworkModel", "NodeProfile",
    "Scenario", "SimBackend", "Slowdown", "Span", "Topology", "Trace",
    "TraceEvent", "build_scenario", "interleave_pods", "list_scenarios",
    "make_heterogeneous_profiles", "make_pod_profiles",
    "make_rack_profiles", "register_scenario", "run_cluster",
    "validate_perfetto",
]
