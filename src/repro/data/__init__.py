"""Deterministic synthetic LM data pipeline.

The environment is offline, so instead of C4 we generate a *learnable*
synthetic token stream: a Zipf-weighted order-1 Markov chain over the
vocabulary.  Every method (AdLoCo / DiLoCo / LocalSGD) consumes the same
per-shard stream, so convergence comparisons are apples-to-apples — the
property the paper's Figure 1 needs.

Key requirement from adaptive batching: ``next_batch(b)`` must accept a
*different* b every call (the norm test grows it), and stay deterministic
given (seed, shard, call sequence).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class MarkovTokenStream:
    """Per-shard synthetic stream.  Shards use disjoint RNG streams but a
    *shared* transition structure (same underlying distribution D, distinct
    samples — matching the paper's i.i.d. shard assumption)."""

    def __init__(self, vocab_size: int, seq_len: int, shard: int = 0,
                 num_shards: int = 1, seed: int = 0, branch: int = 4):
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.rng = np.random.default_rng(
            np.random.SeedSequence([seed, shard]))
        struct = np.random.default_rng(np.random.SeedSequence([seed, 12345]))
        # Zipfian unigram over vocab
        ranks = np.arange(1, vocab_size + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # sparse Markov: each token transitions to `branch` successors
        self.branch = branch
        self.succ = struct.integers(0, vocab_size, (vocab_size, branch))
        self.mix = 0.8          # P(follow chain) vs unigram resample
        self.tokens_served = 0

    def next_batch(self, batch_size: int):
        """-> {"tokens": (batch_size, seq_len) int32 jnp array}."""
        B, S = batch_size, self.seq_len
        out = np.empty((B, S), np.int64)
        out[:, 0] = self.rng.choice(self.vocab, size=B, p=self.unigram)
        follow = self.rng.random((B, S)) < self.mix
        which = self.rng.integers(0, self.branch, (B, S))
        resample = self.rng.choice(self.vocab, size=(B, S), p=self.unigram)
        for t in range(1, S):
            chained = self.succ[out[:, t - 1], which[:, t]]
            out[:, t] = np.where(follow[:, t], chained, resample[:, t])
        self.tokens_served += B * S
        return {"tokens": jnp.asarray(out, jnp.int32)}


def make_shard_streams(vocab_size: int, seq_len: int, num_shards: int,
                       seed: int = 0):
    """One stream per trainer instance (the paper's D_i shards)."""
    return [MarkovTokenStream(vocab_size, seq_len, shard=i,
                              num_shards=num_shards, seed=seed)
            for i in range(num_shards)]


# ------------------------------------------------------------------
# Convex proxy problem (used by theory-validation benchmarks/tests):
# least squares  f(x; (a,b)) = 0.5 (a.x - b)^2  with known optimum.
# ------------------------------------------------------------------

class QuadraticProblem:
    """Stochastic least-squares with controllable gradient noise sigma."""

    def __init__(self, dim: int = 32, noise: float = 1.0, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.dim = dim
        self.noise = noise
        self.x_star = rng.standard_normal(dim)
        self.rng = rng

    def sample(self, batch_size: int, shard_rng=None):
        rng = shard_rng or self.rng
        A = rng.standard_normal((batch_size, self.dim))
        b = A @ self.x_star + self.noise * rng.standard_normal(batch_size)
        return jnp.asarray(A), jnp.asarray(b)

    @staticmethod
    def loss(x, A, b):
        r = A @ x - b
        return 0.5 * jnp.mean(jnp.square(r))

    @staticmethod
    def per_sample_grads(x, A, b):
        r = A @ x - b                       # (B,)
        return A * r[:, None]               # (B, dim)
