"""Batched serving loop: prefill + autoregressive decode with KV cache.

Small but real: request batching, greedy/temperature sampling, ring-
buffer sliding-window caches for long contexts, per-step jit caching.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig


@dataclass
class GenerationResult:
    tokens: List[List[int]]          # per-request generated ids
    steps: int


@partial(jax.jit, static_argnames=("cfg",))
def _decode_jit(params, cache, token, pos, cfg):
    return models.decode_step(params, cache, token, pos, cfg)


def sample(logits, key, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, *,
             max_new_tokens: int = 32, temperature: float = 0.0,
             cache_len: Optional[int] = None, seed: int = 0,
             frames=None, prefix_emb=None) -> GenerationResult:
    """prompts: (B, S_prompt) int32.  Greedy/temperature batched decode."""
    B, S = prompts.shape
    C = cache_len or (S + max_new_tokens)
    if cfg.is_encoder_decoder:
        assert frames is not None
        cache = models.init_cache(cfg, params, B, C, frames=frames)
        # teacher-force the prompt through decode steps
        logits = None
        for t in range(S):
            logits, cache = _decode_jit(params, cache, prompts[:, t],
                                        jnp.int32(t), cfg)
    else:
        logits_all, cache = models.prefill(params, prompts, cfg, C,
                                           prefix_emb=prefix_emb,
                                           last_only=True)
        logits = logits_all[:, -1]
    key = jax.random.PRNGKey(seed)
    out = []
    tok = sample(logits, key, temperature)
    pos0 = S + (0 if prefix_emb is None else prefix_emb.shape[1])
    for i in range(max_new_tokens):
        out.append(tok)
        key, sub = jax.random.split(key)
        logits, cache = _decode_jit(params, cache, tok,
                                    jnp.int32(pos0 + i), cfg)
        tok = sample(logits, sub, temperature)
    stacked = jnp.stack(out, axis=1)                    # (B, new)
    return GenerationResult(
        tokens=[list(map(int, row)) for row in jax.device_get(stacked)],
        steps=max_new_tokens)
