"""Serving entry points.

Two tiers:

* ``generate`` — static-batch decode: one prefill, then lockstep
  autoregressive decode for every prompt in the batch.  Greedy or
  temperature sampling with a split-before-use key chain (every sampled
  token gets a fresh subkey; no key is ever reused between a sample and
  a split).  ``cache_len`` shorter than prompt + generation is an error
  unless ``ring=True`` explicitly opts into ring-buffer semantics: the
  cache keeps only the last ``cache_len`` positions and attention is
  truncated to that sliding window.
* ``scheduler.ContinuousBatcher`` — paged continuous batching: block
  KV cache, chunked prefill interleaved with decode ticks, traced
  admission (``serve.traffic``), per-request sampling streams.  See
  ``repro/serve/scheduler.py``.

``sample_batched`` is the shared per-lane sampler: greedy where
``temperature == 0``, temperature softmax otherwise, optional top-k
truncation, one PRNG key per lane.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig


@dataclass
class GenerationResult:
    tokens: List[List[int]]          # per-request generated ids
    steps: int


@partial(jax.jit, static_argnames=("cfg",))
def _decode_jit(params, cache, token, pos, cfg):
    return models.decode_step(params, cache, token, pos, cfg)


def sample(logits, key, temperature: float):
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


@jax.jit
def sample_batched(logits, keys, temperature, top_k):
    """Per-lane sampling: logits (B, V); keys (B,) PRNG keys;
    temperature (B,) float32 (0 = greedy); top_k (B,) int32 (0 = no
    top-k).  Greedy lanes ignore their key entirely, so mixed batches
    stay reproducible lane-by-lane."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jnp.clip(top_k, 1, V)
    kth = jnp.take_along_axis(jnp.sort(logits, axis=-1)[:, ::-1],
                              (k - 1)[:, None], axis=1)[:, 0]
    use_k = (top_k > 0)[:, None]
    masked = jnp.where(use_k & (logits < kth[:, None]), -jnp.inf, logits)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, masked / t)
    return jnp.where(temperature > 0.0, sampled.astype(jnp.int32), greedy)


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, *,
             max_new_tokens: int = 32, temperature: float = 0.0,
             cache_len: Optional[int] = None, seed: int = 0,
             frames=None, prefix_emb=None,
             ring: bool = False) -> GenerationResult:
    """prompts: (B, S_prompt) int32.  Greedy/temperature batched decode.

    The decode chain needs ``prefix + prompt + max_new_tokens`` cache
    positions; a smaller ``cache_len`` raises ``ValueError`` unless
    ``ring=True``, which opts into the ring-buffer semantics the cache
    already implements (position p lives in slot p % cache_len):
    attention then only sees the most recent ``cache_len`` positions —
    a sliding window, never silent garbage."""
    B, S = prompts.shape
    P = 0 if prefix_emb is None else prefix_emb.shape[1]
    need = P + S + max_new_tokens
    C = cache_len or need
    if C < need and not ring:
        raise ValueError(
            f"cache_len={C} < prefix+prompt+max_new_tokens={need}: the "
            "cache would silently wrap; pass ring=True to opt into "
            f"sliding-window (last {C} positions) attention")
    if cfg.is_encoder_decoder:
        assert frames is not None
        cache = models.init_cache(cfg, params, B, C, frames=frames)
        # teacher-force the prompt through decode steps
        logits = None
        for t in range(S):
            logits, cache = _decode_jit(params, cache, prompts[:, t],
                                        jnp.int32(t), cfg)
    else:
        logits_all, cache = models.prefill(params, prompts, cfg, C,
                                           prefix_emb=prefix_emb,
                                           last_only=True)
        logits = logits_all[:, -1]
    # split-before-use: the base key only ever feeds jax.random.split;
    # each sampled token consumes its own fresh subkey
    key = jax.random.PRNGKey(seed)
    out = []
    key, sub = jax.random.split(key)
    tok = sample(logits, sub, temperature)
    pos0 = S + P
    for i in range(max_new_tokens):
        out.append(tok)
        key, sub = jax.random.split(key)
        logits, cache = _decode_jit(params, cache, tok,
                                    jnp.int32(pos0 + i), cfg)
        tok = sample(logits, sub, temperature)
    stacked = jnp.stack(out, axis=1)                    # (B, new)
    return GenerationResult(
        tokens=[list(map(int, row)) for row in jax.device_get(stacked)],
        steps=max_new_tokens)
