"""Host-side block accounting for the paged KV cache.

Device memory is one pool of ``num_blocks`` fixed-size blocks
(``models.init_paged_cache``: leaves (L, num_blocks + 1, block_size,
Hk, hd), last row = scratch).  This module owns which lane holds which
physical block: a LIFO free list plus per-lane block-table rows
((n_lanes, nb_max) int32, -1 = unallocated) that the device gather
consumes directly.

Identity position layout: table entry j of a lane covers absolute
positions [j * block_size, (j + 1) * block_size) of that lane's
request — no ring wraparound, so a request's total length is bounded
by ``nb_max * block_size`` while CONCURRENCY is bounded only by the
pool (the point of paging: short requests don't reserve worst-case
dense rows).
"""
from __future__ import annotations

from typing import List

import numpy as np


class BlockPool:
    """Free-list allocator over a pool of fixed-size KV blocks."""

    def __init__(self, num_blocks: int, block_size: int, n_lanes: int,
                 nb_max: int):
        assert num_blocks >= 1 and block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.n_lanes = n_lanes
        self.nb_max = nb_max
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.tables = np.full((n_lanes, nb_max), -1, np.int32)

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to cover positions [0, n_tokens)."""
        return -(-n_tokens // self.block_size)

    def lane_blocks(self, lane: int) -> int:
        return int((self.tables[lane] >= 0).sum())

    # ------------------------------------------------------------ mutation
    def ensure(self, lane: int, n_tokens: int) -> bool:
        """Grow ``lane``'s table until positions [0, n_tokens) are
        covered.  Returns False (no change) if the request outgrew its
        table or the pool is exhausted."""
        need = self.blocks_for(n_tokens)
        if need > self.nb_max:
            return False
        have = self.lane_blocks(lane)
        if need - have > len(self._free):
            return False
        for j in range(have, need):
            self.tables[lane, j] = self._free.pop()
        return True

    def release(self, lane: int) -> None:
        """Return every block the lane holds to the free list."""
        for j in range(self.nb_max):
            b = int(self.tables[lane, j])
            if b >= 0:
                self._free.append(b)
        self.tables[lane, :] = -1

    def no_leak(self) -> bool:
        """True iff every block is home: all tables empty and the free
        list is exactly {0 .. num_blocks-1}."""
        return bool((self.tables < 0).all()) \
            and sorted(self._free) == list(range(self.num_blocks))
