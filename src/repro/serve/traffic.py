"""Request-arrival traces for the serving scheduler and bench.

The registry mirrors the cluster scenario shapes
(``repro.cluster.scenarios``) as *request arrival processes* instead of
congestion processes: the same traffic patterns that stress the
training fabric stress the serving admission layer.

  steady       uniform spacing — the control arm
  bursty       groups of simultaneous arrivals every period
               (cluster ``bursty_congestion`` windows)
  diurnal      arrival rate follows a cosine "day": dense at peak,
               sparse at trough (cluster ``diurnal_congestion``)
  flash_crowd  a background trickle, then a crowd lands at one tick
               (cluster ``flash_crowd_join``)

Every trace is deterministic given (n_requests, seed): shapes come from
closed-form schedules, per-request prompt/generation lengths from a
seeded ``np.random.default_rng``.  ``make_arrivals`` returns tick-sorted
``Arrival`` specs; ``materialize`` turns them into scheduler
``Request`` objects with random token ids.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List

import numpy as np


@dataclass(frozen=True)
class Arrival:
    rid: int
    tick: int
    prompt_len: int
    max_new_tokens: int


_TRACES: Dict[str, Callable[[int], List[int]]] = {}


def register(name: str):
    def deco(fn):
        _TRACES[name] = fn
        return fn
    return deco


def trace_names() -> List[str]:
    return sorted(_TRACES)


@register("steady")
def _steady(n: int) -> List[int]:
    return [2 * i for i in range(n)]


@register("bursty")
def _bursty(n: int) -> List[int]:
    burst, period = 6, 16
    return [(i // burst) * period for i in range(n)]


@register("diurnal")
def _diurnal(n: int) -> List[int]:
    # inter-arrival gap follows one cosine day over the trace: short
    # gaps at the peak (phase 0.5), long gaps at the troughs
    ticks, t = [], 0.0
    for i in range(n):
        phase = i / max(n - 1, 1)
        rate = 0.5 - 0.5 * np.cos(2.0 * np.pi * phase)   # 0 .. 1 .. 0
        ticks.append(int(t))
        t += 1.0 + 6.0 * (1.0 - rate)
    return ticks


@register("flash_crowd")
def _flash_crowd(n: int) -> List[int]:
    # a trickle of n - n//2 requests every 3 ticks; the remaining n//2
    # all land mid-trickle at once
    k = n // 2
    trickle = [3 * i for i in range(n - k)]
    crowd_tick = trickle[len(trickle) // 2] if trickle else 0
    return sorted(trickle + [crowd_tick] * k)


def make_arrivals(name: str, *, n_requests: int, seed: int = 0,
                  prompt_lo: int = 4, prompt_hi: int = 12,
                  new_lo: int = 4, new_hi: int = 10) -> List[Arrival]:
    """Tick-sorted arrival specs for a named trace (deterministic)."""
    ticks = _TRACES[name](n_requests)
    assert ticks == sorted(ticks)
    rng = np.random.default_rng(seed)
    return [Arrival(rid=i, tick=int(t),
                    prompt_len=int(rng.integers(prompt_lo, prompt_hi + 1)),
                    max_new_tokens=int(rng.integers(new_lo, new_hi + 1)))
            for i, t in enumerate(ticks)]


def materialize(arrivals: List[Arrival], vocab_size: int, *,
                seed: int = 0, temperature: float = 0.0, top_k: int = 0):
    """[(tick, Request)] with deterministic random prompt token ids."""
    from repro.serve.scheduler import Request
    rng = np.random.default_rng(seed)
    out = []
    for a in arrivals:
        toks = rng.integers(0, vocab_size, (a.prompt_len,))
        out.append((a.tick, Request(rid=a.rid, tokens=[int(t) for t in toks],
                                    max_new_tokens=a.max_new_tokens,
                                    temperature=temperature, top_k=top_k)))
    return out
