"""Continuous batching: a slot-based scheduler over the per-request-
position decode path (``decode_step`` with a (B,) ``pos`` vector).

Requests join mid-flight: a finished slot is immediately refilled from
the queue (prefill writes the new request's KV into that slot's rows of
the shared batched cache), so the decode batch never drains to run one
straggler — the serving-side analogue of the paper's "keep hardware
busy" goal.

Decoder-only architectures (dense / moe / ssm / hybrid).  Greedy
sampling (extend ``_select`` for temperature).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig


@dataclass
class Request:
    rid: int
    tokens: List[int]                    # prompt
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@partial(jax.jit, static_argnames=("cfg",))
def _decode_vec(params, cache, token, pos, cfg):
    return models.decode_step(params, cache, token, pos, cfg)


class ContinuousBatcher:
    """Fixed-slot continuous batcher.

    ``cache_len`` bounds prompt+generation length per request.  All
    slots share one batched cache pytree (leaves (L, n_slots, ...)), so
    a single jitted ``decode_step`` serves every active request at its
    own position each step.
    """

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 cache_len: int = 128):
        assert not cfg.is_encoder_decoder, \
            "continuous batching supports decoder-only archs"
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.cache = models.init_cache(cfg, params, n_slots, cache_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros((n_slots,), np.int32)        # next position
        self.last_token = np.zeros((n_slots,), np.int32)
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.steps = 0

    # ------------------------------------------------------------- API
    def submit(self, req: Request) -> None:
        assert len(req.tokens) + req.max_new_tokens <= self.cache_len, \
            "request exceeds cache_len"
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Drive until queue and slots drain; returns finished requests."""
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            self.step()
        return self.finished

    # ----------------------------------------------------------- internals
    def _admit(self) -> None:
        """Fill free slots from the queue (prefill into slot rows)."""
        for i in range(self.n_slots):
            if self.slot_req[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray([req.tokens], jnp.int32)       # (1, S)
            logits, pcache = models.prefill(
                self.params, prompt, self.cfg, self.cache_len,
                last_only=True)
            # write the single-request cache into slot i
            self.cache = jax.tree.map(
                lambda big, small: big.at[:, i].set(small[:, 0]),
                self.cache, pcache)
            self.slot_req[i] = req
            self.pos[i] = len(req.tokens)
            tok = int(jnp.argmax(logits[0, -1]))
            req.generated.append(tok)
            self.last_token[i] = tok
            self._retire(i)

    def _retire(self, i: int) -> None:
        req = self.slot_req[i]
        if req is not None and req.done:
            self.finished[req.rid] = req
            self.slot_req[i] = None
            self.pos[i] = 0

    def step(self) -> None:
        """One scheduler tick: admit, one batched decode, retire."""
        self._admit()
        active = [i for i in range(self.n_slots)
                  if self.slot_req[i] is not None]
        if not active:
            return
        tokens = jnp.asarray(self.last_token, jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)               # (n_slots,)
        logits, self.cache = _decode_vec(self.params, self.cache,
                                         tokens, pos, self.cfg)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1
        for i in active:
            req = self.slot_req[i]
            req.generated.append(int(nxt[i]))
            self.last_token[i] = nxt[i]
            self.pos[i] += 1
            self._retire(i)
