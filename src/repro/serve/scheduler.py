"""Continuous batching schedulers: paged (block-table) and dense (slot).

``ContinuousBatcher`` is the paged scheduler: requests share one pool of
fixed-size KV blocks (``serve.paged_cache.BlockPool`` on the host,
``models.init_paged_cache`` on the device), so the number of requests
in flight is bounded by total cache *memory*, not by a preallocated
``(L, n_slots, cache_len, ...)`` worst-case shape — short requests hold
only the blocks they touch.  Each scheduler tick:

  1. admit + prefill: FIFO head-of-line admission from the queue into
     free lanes (blocks for the whole prompt are claimed up front);
     every prefilling lane then advances at most ONE chunk
     (``chunk_size`` tokens) through ``prefill_chunk_paged``, so a long
     prompt never stalls the decode batch.  A request that finishes at
     prefill (``max_new_tokens=1``) retires immediately and its lane is
     re-scanned within the same tick.
  2. decode: all fully-prefilled lanes take one ``decode_step_paged``
     in lockstep at their own positions.  Decode blocks are allocated
     on demand; a lane that cannot get its next block stalls (masked
     via ``active``) and retries next tick.  If EVERY decode lane is
     stalled the youngest admission is preempted — its blocks are
     freed and the request requeued at the FRONT of the queue keeping
     its generated tokens (resume re-prefills prompt + generated).

Sampling is batched (``serve.sample_batched``: greedy / temperature /
top-k per lane) with counter-based per-request PRNG streams —
``fold_in(fold_in(base, rid), n_generated)`` — so sampled output is
reproducible regardless of scheduling order, preemption included.

``DenseBatcher`` keeps the seed-era fixed-slot design (one dense
``(L, n_slots, cache_len, ...)`` cache, whole-prompt prefill into slot
rows) as the reference arm for parity tests and the bench, with the
seed bugs fixed: freed slots are masked out of the decode write path
instead of scribbling on row 0, slots freed during admission are
re-scanned in the same tick, and a ``run`` budget no longer silently
drops queued or in-flight work (see ``pending`` / ``on_budget``).

Decoder-only architectures (dense / moe / ssm / hybrid).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig
from repro.serve import sample_batched
from repro.serve.paged_cache import BlockPool


@dataclass
class Request:
    rid: int
    tokens: List[int]                    # prompt
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    temperature: float = 0.0             # 0 = greedy
    top_k: int = 0                       # 0 = no top-k filter

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class BudgetExceeded(RuntimeError):
    """Raised by ``run(on_budget="raise")`` when the step budget is hit
    with work outstanding.  ``.pending`` lists the unfinished requests
    (in-flight first, then queued)."""

    def __init__(self, pending: List[Request]):
        super().__init__(f"step budget exhausted with {len(pending)} "
                         "unfinished requests")
        self.pending = pending


@dataclass
class ServeReport:
    """Deterministic tick-based metrics from ``run_trace``."""
    ticks: int
    idle_ticks: int
    requests_finished: int
    requests_pending: int
    tokens: int
    tokens_per_tick: float
    p50_latency: float                   # submit -> finish, ticks
    p99_latency: float
    p50_ttft: float                      # submit -> first token, ticks
    max_concurrency: int                 # peak simultaneously-resident
    mean_occupancy: float                # resident lanes / n_lanes
    peak_blocks: int                     # 0 for the dense batcher
    preemptions: int


@partial(jax.jit, static_argnames=("cfg",))
def _decode_vec(params, cache, token, pos, cfg, active):
    return models.decode_step(params, cache, token, pos, cfg, active=active)


@partial(jax.jit, static_argnames=("cfg", "block_size"))
def _decode_paged(params, cache, token, pos, cfg, tables, active,
                  block_size):
    return models.decode_step_paged(params, cache, token, pos, cfg,
                                    tables, active, block_size=block_size)


@partial(jax.jit, static_argnames=("cfg", "block_size"))
def _prefill_chunk(params, cache, tokens, pos0, cfg, table_row, lane,
                   block_size):
    return models.prefill_chunk_paged(params, cache, tokens, pos0, cfg,
                                      table_row, lane,
                                      block_size=block_size)


class _BatcherBase:
    """Queue / budget / metrics machinery shared by both batchers."""

    def __init__(self, cfg: ModelConfig, n_lanes: int, seed: int):
        assert not cfg.is_encoder_decoder, \
            "continuous batching supports decoder-only archs"
        self.cfg = cfg
        self.n_lanes = n_lanes
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self.steps = 0
        self.idle_ticks = 0
        self.preemptions = 0
        self._key = jax.random.PRNGKey(seed)
        self._arrive: Dict[int, int] = {}
        self._admit_seq: Dict[int, int] = {}   # rid -> first-admission order
        self._first_tok: Dict[int, int] = {}
        self._finish: Dict[int, int] = {}
        self._occupancy: List[int] = []
        self._peak_blocks = 0

    # -------------------------------------------------------------- API
    def submit(self, req: Request) -> None:
        self._validate(req)
        self._arrive.setdefault(req.rid, self.steps)
        self.queue.append(req)

    @property
    def pending(self) -> List[Request]:
        """Unfinished requests: in-flight (admission order), then queued."""
        return self._inflight() + list(self.queue)

    def step(self) -> bool:
        """One scheduler tick.  Returns whether any work happened."""
        worked = self._tick()
        if worked:
            self.steps += 1
            self._occupancy.append(self._busy_count())
        return worked

    def run(self, max_steps: int = 10_000, *,
            on_budget: str = "return") -> Dict[int, Request]:
        """Drive until queue and lanes drain or the step budget is hit.

        On budget exhaustion unfinished requests are NOT lost: they stay
        queued/in-flight (``self.pending``; ``run`` may be called again
        to resume).  ``on_budget="raise"`` raises ``BudgetExceeded``
        carrying the pending list instead of returning."""
        assert on_budget in ("return", "raise")
        while self.queue or self._busy_count():
            if self.steps >= max_steps:
                if on_budget == "raise":
                    raise BudgetExceeded(self.pending)
                break
            if not self.step():
                raise RuntimeError("scheduler stalled: head request "
                                   "cannot be admitted")
        return self.finished

    def run_trace(self, arrivals: List[Tuple[int, Request]], *,
                  max_steps: int = 1_000_000) -> ServeReport:
        """Drive a timed arrival trace: ``arrivals`` is tick-sorted
        [(tick, Request)] (see ``serve.traffic.materialize``).  Requests
        are submitted when the scheduler clock reaches their tick; the
        clock fast-forwards over idle gaps (counted in ``idle_ticks``)."""
        i = 0
        while True:
            while i < len(arrivals) and arrivals[i][0] <= self.steps:
                self.submit(arrivals[i][1])
                i += 1
            if not self.queue and not self._busy_count():
                if i >= len(arrivals):
                    break
                self.idle_ticks += arrivals[i][0] - self.steps
                self.steps = arrivals[i][0]
                continue
            if self.steps >= max_steps:
                break
            self.step()
        return self.report()

    def report(self) -> ServeReport:
        lat = [self._finish[r] - self._arrive[r] for r in self.finished]
        ttft = [self._first_tok[r] - self._arrive[r] for r in self.finished
                if r in self._first_tok]
        occ = self._occupancy or [0]
        return ServeReport(
            ticks=self.steps,
            idle_ticks=self.idle_ticks,
            requests_finished=len(self.finished),
            requests_pending=len(self.pending),
            tokens=sum(len(r.generated) for r in self.finished.values()),
            tokens_per_tick=(sum(len(r.generated)
                                 for r in self.finished.values())
                             / max(self.steps, 1)),
            p50_latency=float(np.percentile(lat, 50)) if lat else 0.0,
            p99_latency=float(np.percentile(lat, 99)) if lat else 0.0,
            p50_ttft=float(np.percentile(ttft, 50)) if ttft else 0.0,
            max_concurrency=max(occ),
            mean_occupancy=float(np.mean(occ)) / self.n_lanes,
            peak_blocks=self._peak_blocks,
            preemptions=self.preemptions,
        )

    # ------------------------------------------------------------ shared
    def _sample_lanes(self, logits_rows, reqs: List[Request]) -> np.ndarray:
        """Sample one token per row with each request's settings and its
        counter-based PRNG stream (rid x n_generated)."""
        keys, temps, tks = [], [], []
        for req in reqs:
            rk = jax.random.fold_in(self._key, req.rid)
            keys.append(jax.random.fold_in(rk, len(req.generated)))
            temps.append(req.temperature)
            tks.append(req.top_k)
        toks = sample_batched(logits_rows, jnp.stack(keys),
                              jnp.asarray(temps, jnp.float32),
                              jnp.asarray(tks, jnp.int32))
        return np.asarray(toks)

    def _record_token(self, req: Request, tok: int) -> None:
        if not req.generated:
            self._first_tok.setdefault(req.rid, self.steps)
        req.generated.append(tok)

    # ---------------------------------------------------------- abstract
    def _validate(self, req: Request) -> None:
        raise NotImplementedError

    def _tick(self) -> bool:
        raise NotImplementedError

    def _busy_count(self) -> int:
        raise NotImplementedError

    def _inflight(self) -> List[Request]:
        raise NotImplementedError


class ContinuousBatcher(_BatcherBase):
    """Paged continuous batcher (see module docstring).

    ``n_slots`` is the lane count (decode batch width); ``cache_len``
    bounds a single request's prompt+generation length.  ``num_blocks``
    defaults to ``n_slots * ceil(cache_len / block_size)`` — the same
    memory a dense batcher of that geometry preallocates — but unlike
    the dense batcher the blocks are shared, so more than ``n_slots``
    requests' worth of SHORT sequences fit (raise ``n_slots`` to use
    the headroom).  ``chunk_size=None`` prefills whole prompts in one
    chunk per tick."""

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 cache_len: int = 128, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 chunk_size: Optional[int] = None, seed: int = 0):
        super().__init__(cfg, n_slots, seed)
        self.params = params
        self.cache_len = cache_len
        self.block_size = block_size
        self.nb_max = -(-cache_len // block_size)
        self.num_blocks = num_blocks or n_slots * self.nb_max
        self.chunk_size = chunk_size
        self.pool = BlockPool(self.num_blocks, block_size, n_slots,
                              self.nb_max)
        self.cache = models.init_paged_cache(cfg, n_slots, self.num_blocks,
                                             block_size)
        self.lane_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros((n_slots,), np.int32)      # next position
        self.last_token = np.zeros((n_slots,), np.int32)
        self._seq: List[Optional[List[int]]] = [None] * n_slots
        self._filled = np.zeros((n_slots,), np.int64)
        self._resume_tok: List[Optional[int]] = [None] * n_slots
        self._lane_order = np.zeros((n_slots,), np.int64)
        self._admit_counter = 0

    # ------------------------------------------------------------- hooks
    def _validate(self, req: Request) -> None:
        need = len(req.tokens) + req.max_new_tokens
        assert need <= self.cache_len, "request exceeds cache_len"
        assert self.pool.blocks_for(need) <= self.num_blocks, \
            "request exceeds total block pool"

    def _busy_count(self) -> int:
        return sum(r is not None for r in self.lane_req)

    def _inflight(self) -> List[Request]:
        lanes = [i for i in range(self.n_lanes)
                 if self.lane_req[i] is not None]
        return [self.lane_req[i]
                for i in sorted(lanes, key=lambda i: self._lane_order[i])]

    def _tick(self) -> bool:
        worked = self._admit_and_prefill()
        worked |= self._decode()
        self._peak_blocks = max(self._peak_blocks, self.pool.used_blocks)
        return worked

    # --------------------------------------------------------- internals
    def _zero_lane_state(self, lane: int) -> None:
        # SSM/hybrid decode state is per-lane and must not leak across
        # occupants (attention blocks need no reset: slots beyond a
        # lane's write position are causally masked)
        if "conv" in self.cache:
            self.cache["conv"] = self.cache["conv"].at[:, lane].set(0)
            self.cache["ssm"] = self.cache["ssm"].at[:, lane].set(0)

    def _admit_and_prefill(self) -> bool:
        """FIFO head-of-line admission + at most one prefill chunk per
        lane occupant.  Lanes freed by a request finishing AT prefill
        are re-scanned within the same tick."""
        worked = False
        advanced = set()                      # (lane, rid) chunked this tick
        progress = True
        while progress:
            progress = False
            # admit the queue head while a lane + its prompt blocks fit
            while self.queue:
                free = [i for i in range(self.n_lanes)
                        if self.lane_req[i] is None]
                if not free:
                    break
                req = self.queue[0]
                lane = free[0]
                # resume keeps generated tokens: re-prefill all but the
                # last, which becomes the next token to decode
                seq = list(req.tokens) + req.generated[:-1]
                if not self.pool.ensure(lane, len(seq)):
                    break                     # head-of-line: wait, not skip
                self.queue.popleft()
                self.lane_req[lane] = req
                self._seq[lane] = seq
                self._filled[lane] = 0
                self._resume_tok[lane] = (req.generated[-1]
                                          if req.generated else None)
                self._zero_lane_state(lane)
                self._lane_order[lane] = self._admit_counter
                self._admit_seq.setdefault(req.rid, self._admit_counter)
                self._admit_counter += 1
                worked = True
            # one chunk per prefilling occupant
            for lane in range(self.n_lanes):
                req = self.lane_req[lane]
                if req is None:
                    continue
                seq = self._seq[lane]
                if self._filled[lane] >= len(seq) \
                        or (lane, req.rid) in advanced:
                    continue
                advanced.add((lane, req.rid))
                lo = int(self._filled[lane])
                hi = min(lo + (self.chunk_size or len(seq)), len(seq))
                chunk = jnp.asarray([seq[lo:hi]], jnp.int32)
                logits, self.cache = _prefill_chunk(
                    self.params, self.cache, chunk, jnp.int32(lo),
                    self.cfg, jnp.asarray(self.pool.tables[lane]),
                    jnp.int32(lane), self.block_size)
                self._filled[lane] = hi
                worked = True
                if hi < len(seq):
                    continue
                # prefill complete -> decode phase
                self.pos[lane] = len(seq)
                if self._resume_tok[lane] is not None:
                    self.last_token[lane] = self._resume_tok[lane]
                    self._resume_tok[lane] = None
                else:
                    tok = int(self._sample_lanes(logits, [req])[0])
                    self._record_token(req, tok)
                    self.last_token[lane] = tok
                    if req.done:
                        self._retire(lane)
                        progress = True       # re-scan the freed lane
        return worked

    def _decode(self) -> bool:
        decoding = [i for i in range(self.n_lanes)
                    if self.lane_req[i] is not None
                    and self._filled[i] >= len(self._seq[i])]
        if not decoding:
            return False
        # claim each lane's write block; preempt the youngest admission
        # if EVERY decode lane is stalled on the pool
        did_preempt = False
        while True:
            ready = [i for i in decoding
                     if self.pool.ensure(i, int(self.pos[i]) + 1)]
            if ready or not decoding:
                break
            victim = max(decoding, key=lambda i: self._lane_order[i])
            self._preempt(victim)
            did_preempt = True
            decoding.remove(victim)
        if not ready:
            return did_preempt
        active = np.zeros((self.n_lanes,), bool)
        active[ready] = True
        logits, self.cache = _decode_paged(
            self.params, self.cache,
            jnp.asarray(self.last_token), jnp.asarray(self.pos), self.cfg,
            jnp.asarray(self.pool.tables), jnp.asarray(active),
            self.block_size)
        reqs = [self.lane_req[i] for i in ready]
        toks = self._sample_lanes(logits[jnp.asarray(ready)], reqs)
        for j, i in enumerate(ready):
            req = self.lane_req[i]
            self._record_token(req, int(toks[j]))
            self.last_token[i] = toks[j]
            self.pos[i] += 1
            if req.done:
                self._retire(i)
        return True

    def _preempt(self, lane: int) -> None:
        req = self.lane_req[lane]
        self._free_lane(lane)
        self.queue.appendleft(req)            # resumes first, FIFO kept
        self.preemptions += 1

    def _retire(self, lane: int) -> None:
        req = self.lane_req[lane]
        self.finished[req.rid] = req
        self._finish[req.rid] = self.steps
        self._free_lane(lane)

    def _free_lane(self, lane: int) -> None:
        self.pool.release(lane)
        self.lane_req[lane] = None
        self._seq[lane] = None
        self._filled[lane] = 0
        self._resume_tok[lane] = None
        self.pos[lane] = 0
        self.last_token[lane] = 0


class DenseBatcher(_BatcherBase):
    """Seed-era fixed-slot batcher, kept as the reference arm.

    One dense ``(L, n_slots, cache_len, ...)`` cache: every slot
    reserves worst-case memory for its request, so concurrency is
    pinned at ``n_slots`` no matter how short the requests are —
    exactly the wall the paged batcher removes."""

    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 cache_len: int = 128, seed: int = 0):
        super().__init__(cfg, n_slots, seed)
        self.params = params
        self.cache_len = cache_len
        self.cache = models.init_cache(cfg, params, n_slots, cache_len)
        self.lane_req: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros((n_slots,), np.int32)
        self.last_token = np.zeros((n_slots,), np.int32)
        self._lane_order = np.zeros((n_slots,), np.int64)
        self._admit_counter = 0

    # ------------------------------------------------------------- hooks
    def _validate(self, req: Request) -> None:
        assert len(req.tokens) + req.max_new_tokens <= self.cache_len, \
            "request exceeds cache_len"

    def _busy_count(self) -> int:
        return sum(r is not None for r in self.lane_req)

    def _inflight(self) -> List[Request]:
        lanes = [i for i in range(self.n_lanes)
                 if self.lane_req[i] is not None]
        return [self.lane_req[i]
                for i in sorted(lanes, key=lambda i: self._lane_order[i])]

    def _tick(self) -> bool:
        worked = self._admit()
        worked |= self._decode()
        return worked

    # --------------------------------------------------------- internals
    def _admit(self) -> bool:
        """Whole-prompt prefill into free slot rows; slots freed by a
        request finishing at prefill are re-scanned in the same tick."""
        worked = False
        progress = True
        while progress:
            progress = False
            for i in range(self.n_lanes):
                if self.lane_req[i] is not None or not self.queue:
                    continue
                req = self.queue.popleft()
                prompt = jnp.asarray([req.tokens], jnp.int32)
                logits, pcache = models.prefill(
                    self.params, prompt, self.cfg, self.cache_len,
                    last_only=True)
                self.cache = jax.tree.map(
                    lambda big, small: big.at[:, i].set(small[:, 0]),
                    self.cache, pcache)
                self.lane_req[i] = req
                self.pos[i] = len(req.tokens)
                self._lane_order[i] = self._admit_counter
                self._admit_seq.setdefault(req.rid, self._admit_counter)
                self._admit_counter += 1
                tok = int(self._sample_lanes(logits[:, -1], [req])[0])
                self._record_token(req, tok)
                self.last_token[i] = tok
                worked = True
                if req.done:
                    self._retire(i)
                    progress = True
        return worked

    def _decode(self) -> bool:
        lanes = [i for i in range(self.n_lanes)
                 if self.lane_req[i] is not None]
        if not lanes:
            return False
        active = np.zeros((self.n_lanes,), bool)
        active[lanes] = True
        logits, self.cache = _decode_vec(
            self.params, self.cache, jnp.asarray(self.last_token),
            jnp.asarray(self.pos), self.cfg, jnp.asarray(active))
        reqs = [self.lane_req[i] for i in lanes]
        toks = self._sample_lanes(logits[jnp.asarray(lanes)], reqs)
        for j, i in enumerate(lanes):
            req = self.lane_req[i]
            self._record_token(req, int(toks[j]))
            self.last_token[i] = toks[j]
            self.pos[i] += 1
            if req.done:
                self._retire(i)
        return True

    def _retire(self, i: int) -> None:
        req = self.lane_req[i]
        self.finished[req.rid] = req
        self._finish[req.rid] = self.steps
        self.lane_req[i] = None
        self.pos[i] = 0
        self.last_token[i] = 0
