"""Correctness of the §Perf optimization paths against their reference
implementations (EXPERIMENTS.md §Perf): banded sliding-window attention,
sequential sub-block SSM scan, grouped layer scan, grouped MoE dispatch,
and last-only prefill logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests ride along whenever hypothesis is installed (CI
# pins it); without it the whole module is skipped rather than
# erroring at collection
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import models
from repro.configs import get_config, reduced
from repro.models import layers as L
import repro.models.lm as lm


# ----------------------------------------------- banded attention

def _qkv(key, B, S, H, Hk, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, Hk, hd)),
            jax.random.normal(ks[2], (B, S, Hk, hd)))


@pytest.mark.parametrize("S,w", [(256, 64), (128, 32), (512, 128)])
def test_banded_matches_masked_sdpa(S, w):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, S, 4, 2, 32)
    ref = L.sdpa(q, k, v, causal=True, window=w)
    got = L.sdpa_banded(q, k, v, window=w)
    np.testing.assert_allclose(ref, got, atol=2e-5, rtol=2e-5)


def test_banded_first_block_no_left_leak():
    """Queries in block 0 must not see the zero-padded phantom block."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 128, 2, 1, 16)
    ref = L.sdpa(q[:, :64], k[:, :64], v[:, :64], causal=True, window=64)
    got = L.sdpa_banded(q, k, v, window=64)[:, :64]
    np.testing.assert_allclose(ref, got, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4).map(lambda i: 2 ** i))
def test_property_banded_any_window(wpow):
    S = 4 * wpow
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, S, 2, 2, 8)
    ref = L.sdpa(q, k, v, causal=True, window=wpow)
    got = L.sdpa_banded(q, k, v, window=wpow)
    np.testing.assert_allclose(ref, got, atol=3e-5, rtol=3e-5)


# ----------------------------------------------- sequential SSM scan

def _ssm_inputs(key, B=2, S=100, di=24, n=8):
    ks = jax.random.split(key, 5)
    u = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) - 1)
    A = jax.random.normal(ks[2], (di, n)) * 0.1
    Bm = jax.random.normal(ks[3], (B, S, n))
    Cm = jax.random.normal(ks[4], (B, S, n))
    return u, dt, A, Bm, Cm


def test_seq_scan_matches_chunked():
    u, dt, A, Bm, Cm = _ssm_inputs(jax.random.PRNGKey(0))
    y1, h1 = L.ssm_scan_chunked(u, dt, A, Bm, Cm)
    y2, h2 = L.ssm_scan_seq(u, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(h1, h2, atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(3, 70))
def test_property_seq_scan_any_length(S):
    u, dt, A, Bm, Cm = _ssm_inputs(jax.random.PRNGKey(3), B=1, S=S, di=8, n=4)
    y1, h1 = L.ssm_scan_chunked(u, dt, A, Bm, Cm, chunk=16)
    y2, h2 = L.ssm_scan_seq(u, dt, A, Bm, Cm, sub=8)
    np.testing.assert_allclose(y1, y2, atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(h1, h2, atol=3e-5, rtol=3e-5)


def test_mamba_forward_return_state_consistent():
    """return_state must give the same state an explicit second scan
    would (what prefill relied on before §Perf Opt B)."""
    cfg = reduced(get_config("falcon-mamba-7b"))
    p = L.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    out, state = L.mamba_forward(p, x, cfg, return_state=True)
    out2 = L.mamba_forward(p, x, cfg)
    np.testing.assert_allclose(out, out2, atol=1e-6)
    assert state["ssm"].shape == (2, cfg.d_inner, cfg.ssm.state_dim)
    assert state["conv"].shape == (2, cfg.ssm.conv_dim - 1, cfg.d_inner)


# ----------------------------------------------- grouped layer scan

def test_grouped_scan_matches_flat():
    cfg = reduced(get_config("gemma3-4b")).with_overrides(
        num_layers=8, global_every=4)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 128)), jnp.int32)
    logits_grouped, _ = lm.forward(params, toks, cfg, remat=False)
    orig = lm._grouped
    lm._grouped = lambda c: None          # force the flat traced path
    try:
        logits_flat, _ = lm.forward(params, toks, cfg, remat=False)
    finally:
        lm._grouped = orig
    np.testing.assert_allclose(
        np.asarray(logits_grouped, np.float32),
        np.asarray(logits_flat, np.float32), atol=2e-4, rtol=2e-4)


def test_grouped_scan_with_tail_layers():
    """num_layers not divisible by global_every -> unrolled tail."""
    cfg = reduced(get_config("gemma3-4b")).with_overrides(
        num_layers=7, global_every=3)
    assert lm._grouped(cfg) == (2, 3, 1)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray([[1, 2, 3, 4] * 32], jnp.int32)
    logits, aux = lm.forward(params, toks, cfg, remat=False)
    assert logits.shape == (1, 128, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_grouped_prefill_cache_layer_order():
    """Grouped prefill must stack cache slices in true layer order."""
    cfg = reduced(get_config("gemma3-4b")).with_overrides(
        num_layers=7, global_every=3)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray([[5, 6, 7, 8] * 32], jnp.int32)
    logits, cache = lm.prefill(params, toks, cfg, cache_len=128)
    assert cache["k"].shape[0] == cfg.num_layers
    # decode continuation must agree with teacher-forced forward
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    logits2, _ = lm.decode_step(params, cache, nxt, jnp.int32(128), cfg)
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    ref_logits, _ = lm.forward(params, toks2, cfg, remat=False)
    np.testing.assert_allclose(
        np.asarray(logits2, np.float32),
        np.asarray(ref_logits[:, -1], np.float32), atol=5e-2, rtol=5e-2)


# ----------------------------------------------- grouped MoE dispatch

def test_moe_grouped_matches_flat_when_capacity_ample():
    cfg = reduced(get_config("deepseek-moe-16b"))
    p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.1
    y3, aux3 = L.moe_block(p, x, cfg, capacity_factor=8.0)
    yf, auxf = L.moe_block(p, x.reshape(64, cfg.d_model), cfg,
                           capacity_factor=8.0)
    np.testing.assert_allclose(y3.reshape(64, -1), yf, atol=1e-5, rtol=1e-4)


# ----------------------------------------------- last-only prefill

def test_prefill_last_only_matches_full():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    full, cache_a = lm.prefill(params, toks, cfg, cache_len=16)
    last, cache_b = lm.prefill(params, toks, cfg, cache_len=16,
                               last_only=True)
    assert last.shape == (1, 1, cfg.vocab_size)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32), atol=1e-4, rtol=1e-4)
    for k in cache_a:
        np.testing.assert_allclose(np.asarray(cache_a[k], np.float32),
                                   np.asarray(cache_b[k], np.float32))
