"""Tests for CheckMerge/DoMerge (Algorithms 1-2), SwitchMode, comms
metering, and the DiLoCo step primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests ride along whenever hypothesis is installed (CI
# pins it); without it the whole module is skipped rather than
# erroring at collection
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import optim
from repro.core.comms import CommsMeter, param_bytes
from repro.core.diloco import (StepCache, make_inner_step, make_outer_step,
                               merge_params, reshape_for_plan)
from repro.core.mit import (TrainerPoolState, TrainerState, check_merge,
                            consolidate, do_merge)
from repro.core.switch import plan_execution


# ------------------------------------------------------------------
# CheckMerge (Algorithm 1)
# ------------------------------------------------------------------

def test_check_merge_selects_w_worst():
    assert check_merge([10, 2, 7, 5], 2) == [1, 3]


def test_check_merge_empty_cases():
    assert check_merge([5], 1) == []          # k <= 1
    assert check_merge([5, 6], 0) == []       # w == 0


def test_check_merge_clamps_w_to_pool():
    # Algorithm 1 clamps w to k: both edges merge the whole pool
    # instead of silently skipping the merge
    assert check_merge([5, 6], 2) == [0, 1]   # w == k
    assert check_merge([5, 6], 3) == [0, 1]   # w > k
    assert check_merge([9, 2, 7], 99) == [1, 2, 0]


def test_check_merge_ties_stable():
    assert check_merge([3, 3, 3], 2) == [0, 1]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 1000), min_size=2, max_size=16),
       st.integers(1, 16))
def test_property_check_merge_returns_minima(batches, w):
    ids = check_merge(batches, w)
    eff = min(w, len(batches))                # w > k clamps to k
    assert len(ids) == eff
    chosen = sorted(batches[i] for i in ids)
    rest = sorted(batches[i] for i in range(len(batches)) if i not in ids)
    assert all(c <= r for c, r in zip(chosen[-1:], rest[:1]))


# ------------------------------------------------------------------
# DoMerge (Algorithm 2)
# ------------------------------------------------------------------

def _mk_pool(params_list, breqs):
    trainers = [TrainerState(tid=i, params=p, outer_opt_state=(),
                             inner_opt_states=[()], requested_batch=b,
                             streams=[f"s{i}"])
                for i, (p, b) in enumerate(zip(params_list, breqs))]
    return TrainerPoolState(trainers=trainers)


def test_merge_weighted_average_exact():
    p1 = {"w": jnp.asarray([1.0, 1.0])}
    p2 = {"w": jnp.asarray([4.0, 0.0])}
    pool = _mk_pool([p1, p2], [1, 3])
    pool = do_merge(pool, [0, 1], step=1)
    assert pool.k == 1
    merged = pool.trainers[0].params["w"]
    np.testing.assert_allclose(np.asarray(merged), [3.25, 0.25], rtol=1e-6)
    # representative is the max-b trainer
    assert pool.trainers[0].tid == 1


def test_merge_conserves_weighted_mean_property():
    rng = np.random.default_rng(0)
    ps = [{"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
          for _ in range(4)]
    bs = [2, 9, 4, 1]
    pool = _mk_pool(ps, bs)
    ids = [0, 3, 2]
    expect = sum(b * np.asarray(ps[i]["w"]) for i, b in
                 zip(ids, [bs[i] for i in ids])) / sum(bs[i] for i in ids)
    pool = do_merge(pool, ids, step=1)
    assert pool.k == 2
    rep = [t for t in pool.trainers if t.tid == 2][0]   # b=4 is max of set
    np.testing.assert_allclose(np.asarray(rep.params["w"]), expect,
                               rtol=1e-5)


def test_merge_pool_contracts_and_streams_union():
    ps = [{"w": jnp.ones(2) * i} for i in range(3)]
    pool = _mk_pool(ps, [1, 2, 3])
    pool = do_merge(pool, [0, 1], step=1)
    assert pool.k == 2
    rep = [t for t in pool.trainers if t.tid == 1][0]
    assert set(rep.streams) == {"s0", "s1"}
    assert pool.comms.events == 1


def test_consolidate_single_trainer_no_comm():
    pool = _mk_pool([{"w": jnp.ones(2)}], [4])
    pool = consolidate(pool, step=9)
    assert pool.comms.events == 0
    np.testing.assert_allclose(np.asarray(pool.global_params["w"]), 1.0)


# ------------------------------------------------------------------
# SwitchMode (paper §4.2)
# ------------------------------------------------------------------

def test_switch_plain_below_max():
    p = plan_execution(5, 64, 2)
    assert p.mode == "plain" and p.accum_steps == 1
    assert p.micro_batch <= 64


def test_switch_band_no_accum():
    """max_batch < b_req <= n*max_batch: stay plain, cap at max_batch."""
    p = plan_execution(100, 64, 2)
    assert p.mode == "plain"
    assert p.micro_batch == 64 and p.accum_steps == 1


def test_switch_accumulates_beyond_n_times_max():
    p = plan_execution(300, 64, 2, bucket=False)
    assert p.mode == "accum"
    assert p.micro_batch == 64
    assert p.accum_steps == 5          # ceil(300/64)


def test_switch_bucketing_powers_of_two():
    p = plan_execution(300, 64, 2, bucket=True)
    assert p.accum_steps == 8          # next pow2 of 5
    p2 = plan_execution(23, 64, 2, bucket=True)
    assert p2.micro_batch == 32


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 10_000), st.integers(1, 256), st.integers(1, 4))
def test_property_switch_effective_batch_covers_request(b_req, mx, n):
    p = plan_execution(b_req, mx, n, bucket=False)
    if p.mode == "accum":
        assert p.effective_batch >= b_req
        assert b_req > n * mx
    else:
        assert p.micro_batch == min(b_req, mx)


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 100_000), st.integers(1, 300), st.integers(1, 8),
       st.booleans())
def test_property_effective_batch_at_most_double_request(b_req, mx, n,
                                                         bucket):
    """Regression: the plan must never consume more than twice the
    requested batch, bucketing included.  Right at the switch boundary
    (b_req = n·max + 1) the power-of-two rounding of the accum count
    lands just under 2x; the clamp in plan_execution makes the bound
    structural, so a future change to the rounding (e.g. bucketing the
    micro batch in accum mode too — the factors would compound) trips
    this test instead of silently inflating data consumption."""
    p = plan_execution(b_req, mx, n, bucket=bucket)
    assert p.effective_batch <= 2 * b_req
    if p.mode == "accum":
        # the plan still covers the request after the clamp
        assert p.effective_batch >= b_req


def test_switch_boundary_overshoot_is_bounded():
    """The worst cases: one past the switch threshold, where the exact
    accum count (n+1) rounds up to the next power of two."""
    for mx in (3, 16, 24, 64):
        for n in (1, 2, 3, 4, 5):
            b_req = n * mx + 1
            p = plan_execution(b_req, mx, n, bucket=True)
            assert p.mode == "accum"
            assert b_req <= p.effective_batch <= 2 * b_req, \
                (b_req, mx, n, p)


def test_bucketed_accum_dense_sweep_holds_both_bounds():
    """Dense sweep over the accum region: bucketed plans always cover
    the request and never exceed twice it (the structural invariant the
    plan_execution clamp guards; its fallback is provably unreachable
    under the current pow2 rounding, so what this pins is the bound
    itself, boundary cases included)."""
    for b_req in range(1, 2000):
        for mx in (4, 7, 16):
            p = plan_execution(b_req, mx, 2, bucket=True)
            assert p.effective_batch <= 2 * b_req, (b_req, mx, p)
            if p.mode == "accum":
                assert p.effective_batch >= b_req, (b_req, mx, p)


# ------------------------------------------------------------------
# DiLoCo primitives
# ------------------------------------------------------------------

def _quad_loss(params, batch):
    r = batch["A"] @ params["x"] - batch["y"]
    return 0.5 * jnp.mean(jnp.square(r)), {}


def test_accum_equals_big_batch_gradient():
    """One inner step with accum=4 micro-batches == one step on the full
    batch (gradient averaging correctness of SwitchMode)."""
    rng = np.random.default_rng(3)
    A = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(32), jnp.float32)
    params = {"x": jnp.zeros(8)}
    opt = optim.sgd(0.1)

    s1 = make_inner_step(_quad_loss, opt, 1)
    s4 = make_inner_step(_quad_loss, opt, 4)
    batch_full = {"A": A[None], "y": y[None]}
    batch_micro = {"A": A.reshape(4, 8, 8), "y": y.reshape(4, 8)}
    p1, _, _, g1 = s1(params, opt.init(params), batch_full)
    p4, _, _, g4 = s4(params, opt.init(params), batch_micro)
    np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p4["x"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1["x"]), np.asarray(g4["x"]),
                               rtol=1e-5)


def test_outer_step_moves_toward_worker_mean():
    """With lr_outer=1, momentum=0: x_new = mean(workers)."""
    opt = optim.sgd(1.0)
    outer = make_outer_step(opt)
    x_prev = {"x": jnp.zeros(4)}
    workers = {"x": jnp.asarray([[1.0, 2, 3, 4], [3.0, 2, 1, 0]])}
    x_new, _ = outer(x_prev, workers, opt.init(x_prev))
    np.testing.assert_allclose(np.asarray(x_new["x"]), [2, 2, 2, 2],
                               rtol=1e-6)


def test_step_cache_buckets():
    opt = optim.sgd(0.1)
    cache = StepCache(_quad_loss, opt)
    p1 = plan_execution(8, 64, 2)
    p2 = plan_execution(8, 64, 2)
    p3 = plan_execution(300, 64, 2)
    cache.get(p1); cache.get(p2); cache.get(p3)
    assert cache.num_compiled == 2


def test_comms_meter_ring_model():
    m = CommsMeter()
    m.record("outer", participants=4, payload_bytes=100, step=1)
    # 2*(p-1)/p * payload * p = 2*3*100 = 600
    assert m.total_bytes == 600
    assert m.events == 1


def test_param_bytes():
    tree = {"a": jnp.zeros((4, 4), jnp.float32), "b": jnp.zeros(8, jnp.bfloat16)}
    assert param_bytes(tree) == 4 * 4 * 4 + 8 * 2
