"""Serve-path regressions: generate's RNG chain, cache_len validation,
the batched sampler, run budgets, traffic traces, block reclamation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models, serve
from repro.configs import get_config, reduced
from repro.serve import sample_batched
from repro.serve.scheduler import (BudgetExceeded, ContinuousBatcher,
                                   Request)
from repro.serve import traffic


def _setup(arch):
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# -------------------------------------------------------- generate RNG
def test_generate_splits_key_before_first_sample():
    """Regression: the seed sampled the first token with the BASE key
    and then fed that same key to jax.random.split, correlating the
    first two draws.  Pin the fixed chain: every sampled token consumes
    a fresh subkey, the base key only ever feeds split."""
    cfg, params = _setup("qwen3-0.6b")
    prompt = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)
    got = serve.generate(params, cfg, prompt, max_new_tokens=4,
                         temperature=1.0, seed=11).tokens[0]

    # reference: replay the split-before-use chain by hand
    C = prompt.shape[1] + 4
    logits_all, cache = models.prefill(params, prompt, cfg, C,
                                       last_only=True)
    logits = logits_all[:, -1]
    key = jax.random.PRNGKey(11)
    subs = []
    for _ in range(5):
        key, sub = jax.random.split(key)
        subs.append(sub)
    want, buggy = [], []
    tok = serve.sample(logits, subs[0], 1.0)
    tok_b = serve.sample(logits, jax.random.PRNGKey(11), 1.0)  # seed bug
    cache_b = cache
    for i in range(4):
        want.append(int(tok[0]))
        buggy.append(int(tok_b[0]))
        logits, cache = models.decode_step(
            params, cache, tok, jnp.int32(prompt.shape[1] + i), cfg)
        logits_b, cache_b = models.decode_step(
            params, cache_b, tok_b, jnp.int32(prompt.shape[1] + i), cfg)
        tok = serve.sample(logits, subs[i + 1], 1.0)
        tok_b = serve.sample(logits_b, subs[i], 1.0)
    assert got == want
    # the buggy chain reuses keys; pin that the fix actually changed the
    # stream (first draw uses a fresh subkey, not the base key)
    assert not np.array_equal(
        np.asarray(jax.random.PRNGKey(11)), np.asarray(subs[0]))
    assert got != buggy or want == buggy  # chains must diverge unless tied


# -------------------------------------------------- cache_len semantics
def test_generate_short_cache_len_raises():
    cfg, params = _setup("qwen3-0.6b")
    prompt = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    with pytest.raises(ValueError, match="ring=True"):
        serve.generate(params, cfg, prompt, max_new_tokens=8, cache_len=10)


def test_generate_ring_opt_in_sliding_window():
    """ring=True: the cache keeps the last cache_len positions — decode
    still produces max_new_tokens and matches a run whose early steps
    fit entirely inside the window."""
    cfg, params = _setup("qwen3-0.6b")
    prompt = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    r = serve.generate(params, cfg, prompt, max_new_tokens=8,
                       cache_len=10, ring=True)
    assert len(r.tokens[0]) == 8
    # while positions fit in the ring (< cache_len), tokens match the
    # unconstrained reference; afterwards the window may diverge
    full = serve.generate(params, cfg, prompt, max_new_tokens=8)
    n_safe = 10 - prompt.shape[1] - 1
    assert r.tokens[0][:n_safe] == full.tokens[0][:n_safe]


# ------------------------------------------------------ batched sampler
def test_sample_batched_greedy_and_topk():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(4)])
    argmax = np.asarray(jnp.argmax(logits, axis=-1))
    # temperature 0 -> greedy regardless of key / top_k
    out = sample_batched(logits, keys, jnp.zeros((4,), jnp.float32),
                         jnp.zeros((4,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), argmax)
    # top_k=1 -> greedy even at high temperature
    out = sample_batched(logits, keys, jnp.full((4,), 5.0, jnp.float32),
                         jnp.ones((4,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), argmax)
    # mixed batch: greedy lanes unaffected by their neighbours' settings
    temps = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32)
    tks = jnp.asarray([0, 5, 0, 0], jnp.int32)
    out = np.asarray(sample_batched(logits, keys, temps, tks))
    assert out[0] == argmax[0] and out[2] == argmax[2]
    # top-k truncation: sampled ids must be among the k best
    top5 = np.argsort(-np.asarray(logits[1]))[:5]
    assert out[1] in top5


# ------------------------------------------------------- traffic traces
def test_traffic_traces_registered_and_deterministic():
    names = traffic.trace_names()
    for want in ("steady", "bursty", "diurnal", "flash_crowd"):
        assert want in names
    for name in names:
        a = traffic.make_arrivals(name, n_requests=12, seed=3)
        b = traffic.make_arrivals(name, n_requests=12, seed=3)
        assert a == b
        ticks = [x.tick for x in a]
        assert ticks == sorted(ticks)
        assert all(x.prompt_len >= 1 and x.max_new_tokens >= 1 for x in a)
    # bursty really bursts: some tick holds >1 arrival
    bt = [x.tick for x in traffic.make_arrivals("bursty", n_requests=8)]
    assert max(bt.count(t) for t in set(bt)) > 1


# ------------------------------------------------------- budget / pending
def test_run_budget_keeps_pending_and_resumes():
    cfg, params = _setup("falcon-mamba-7b")
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (4,))))
               for _ in range(4)]
    want = {}
    ref = ContinuousBatcher(params, cfg, n_slots=2, cache_len=24,
                            block_size=8)
    for i, p in enumerate(prompts):
        ref.submit(Request(rid=i, tokens=p, max_new_tokens=6))
    for i, r in ref.run().items():
        want[i] = r.generated

    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=24,
                           block_size=8)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=6))
    done = cb.run(max_steps=3)
    # nothing is silently dropped: every request is finished or pending
    assert {r.rid for r in cb.pending} | set(done) == set(range(4))
    assert cb.pending                     # budget really cut work short
    done = cb.run()                       # resume to completion
    assert sorted(done) == list(range(4))
    for i in range(4):
        assert done[i].generated == want[i], i


def test_run_budget_raise_carries_pending():
    cfg, params = _setup("stablelm-1.6b")
    cb = ContinuousBatcher(params, cfg, n_slots=1, cache_len=16,
                           block_size=8)
    for i in range(3):
        cb.submit(Request(rid=i, tokens=[1, 2, 3], max_new_tokens=6))
    with pytest.raises(BudgetExceeded) as ei:
        cb.run(max_steps=2, on_budget="raise")
    assert len(ei.value.pending) >= 1
    assert sorted(r.rid for r in ei.value.pending) \
        == sorted(r.rid for r in cb.pending)


# -------------------------------------------- block reclamation / trace
@pytest.mark.parametrize("trace", ["bursty", "flash_crowd"])
def test_randomized_trace_no_block_leak(trace):
    """Drive a traced arrival process end-to-end: every request must
    finish, admission follows arrival order, and every block must come
    home to the free list."""
    cfg, params = _setup("qwen3-0.6b")
    arr = traffic.make_arrivals(trace, n_requests=10, seed=5,
                                prompt_lo=2, prompt_hi=8,
                                new_lo=2, new_hi=6)
    cb = ContinuousBatcher(params, cfg, n_slots=3, cache_len=16,
                           block_size=4, num_blocks=9, chunk_size=4)
    rep = cb.run_trace(traffic.materialize(arr, cfg.vocab_size, seed=5))
    assert rep.requests_finished == 10 and rep.requests_pending == 0
    assert cb.pool.no_leak()
    assert rep.tokens == sum(len(r.generated) for r in cb.finished.values())
    assert 0 < rep.mean_occupancy <= 1.0
    assert rep.peak_blocks <= 9
    # FIFO admission: arrivals (tick-sorted, rids assigned in order)
    # are first admitted in exactly arrival order — head-of-line
    # blocking never lets a later request jump the queue
    orders = [cb._admit_seq[a.rid] for a in arr]
    assert orders == sorted(orders)
