"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family (2 layers, d_model<=512, <=4 experts) runs one
forward/train step on CPU; output shapes + finiteness asserted.  The
FULL configs are exercised via the dry-run only."""
import jax
import jax.numpy as jnp
import pytest

from repro import models, optim
from repro.configs import ARCH_REGISTRY, ASSIGNED_ARCHS, get_config, reduced
from repro.core.diloco import make_inner_step

ALL_ARCHS = ASSIGNED_ARCHS + ["microllama-300m"]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch, key):
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, key)
    batch = models.example_batch(cfg, 2, 32)
    loss, metrics = models.loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, key):
    """One full inner step (grad + AdamW) decreases nothing NaN-ish."""
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, key)
    opt = optim.adamw(1e-3)
    step = make_inner_step(
        lambda p, b: models.loss_fn(p, b, cfg), opt, 1)
    batch = models.example_batch(cfg, 2, 32)
    batch = jax.tree.map(lambda x: x[None], batch)
    p2, _, loss, grads = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch} grad NaN"
    # params actually changed
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch, key):
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, key)
    B, C = 2, 16
    frames = None
    if cfg.is_encoder_decoder:
        frames = jnp.zeros((B, cfg.num_prefix_tokens, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    cache = models.init_cache(cfg, params, B, C, frames=frames)
    tok = jnp.zeros((B,), jnp.int32)
    logits = None
    for pos in range(3):
        logits, cache = models.decode_step(params, cache, tok,
                                           jnp.int32(pos), cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma3-4b", "hymba-1.5b",
                                  "falcon-mamba-7b"])
def test_prefill_matches_decode(arch, key):
    """Prefilling S tokens then decoding token S == forward logits at S.

    Covers: KV cache correctness, ring-buffer positions, RoPE offsets,
    SSM state carry (the core serving invariant)."""
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, key)
    B, S = 1, 12
    batch = models.example_batch(cfg, B, S)
    tokens = batch["tokens"]
    C = 16

    logits_fwd, _ = models.lm.forward(params, tokens, cfg, remat=False)
    logits_pre, cache = models.prefill(params, tokens[:, :-1], cfg, C)
    logits_dec, _ = models.decode_step(params, cache, tokens[:, -1],
                                       jnp.int32(S - 1), cfg)
    ref = logits_fwd[:, -1]
    err = float(jnp.max(jnp.abs(logits_dec - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / scale < 5e-2, f"{arch}: decode/forward mismatch {err}"


def test_vocab_shapes_exact():
    """Full configs carry the exact assigned hyperparameters."""
    expect = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    }
    for arch, (L, d, H, Hk, ff, V) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == \
            (L, d, H, Hk, ff, V), arch


def test_moe_extras():
    ds = get_config("deepseek-moe-16b")
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.num_shared == 2
    gk = get_config("grok-1-314b")
    assert gk.moe.num_experts == 8 and gk.moe.top_k == 2


def test_param_counts_near_nameplate():
    tol = {"qwen3-0.6b": (0.55e9, 0.8e9), "phi3-medium-14b": (13e9, 15.5e9),
           "deepseek-moe-16b": (15e9, 18e9), "stablelm-1.6b": (1.4e9, 1.9e9),
           "hymba-1.5b": (1.3e9, 1.8e9), "grok-1-314b": (300e9, 330e9),
           "gemma3-4b": (3.5e9, 4.5e9), "phi-3-vision-4.2b": (3.5e9, 4.5e9),
           "whisper-small": (0.2e9, 0.4e9), "falcon-mamba-7b": (6.5e9, 8e9)}
    for arch, (lo, hi) in tol.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo},{hi}]"
