import os

# Smoke tests and benches see the real single CPU device — the 512-device
# override belongs to launch/dryrun.py ONLY (see system design docs).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_addoption(parser):
    parser.addoption(
        "--update-goldens", action="store_true", default=False,
        help="rewrite stored golden-trace digests (tests/goldens/) with "
             "the values the current code produces, instead of failing "
             "on drift; commit the resulting diff as the reviewable "
             "record of the behavior change")
