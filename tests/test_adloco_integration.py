"""Integration tests: full AdLoCo (Algorithm 3) behaviour on the convex
proxy + a tiny LM, baseline equivalences, and theory sanity checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config, reduced
from repro.configs.base import AdLoCoConfig
from repro.core import (train_adloco, train_diloco, train_local_sgd)
from repro.data import MarkovTokenStream, QuadraticProblem


class QuadStream:
    def __init__(self, prob, shard, seed=0):
        self.prob = prob
        self.rng = np.random.default_rng(np.random.SeedSequence([seed, shard]))

    def next_batch(self, b):
        A, y = self.prob.sample(b, self.rng)
        return {"A": A, "y": y}


def quad_loss(params, batch):
    r = batch["A"] @ params["x"] - batch["y"]
    return 0.5 * jnp.mean(jnp.square(r)), {}


def _quad_setup(k=3, M=2, dim=16, noise=2.0):
    prob = QuadraticProblem(dim=dim, noise=noise, seed=0)
    keys = jax.random.split(jax.random.PRNGKey(0), k)
    inits = [{"x": jax.random.normal(kk, (dim,))} for kk in keys]
    streams = [QuadStream(prob, i) for i in range(k * M)]
    return prob, inits, streams


BASE = AdLoCoConfig(num_outer_steps=10, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, nodes_per_gpu=2, num_init_trainers=3,
                    initial_batch_size=2, merge_frequency=3, eta=0.8,
                    max_batch=16, inner_optimizer="sgd",
                    stats_probe_size=32)


def test_adloco_converges_on_quadratic():
    prob, inits, streams = _quad_setup()
    pool, hist = train_adloco(quad_loss, inits, streams, BASE)
    d0 = float(jnp.linalg.norm(inits[0]["x"] - prob.x_star))
    d1 = float(jnp.linalg.norm(pool.global_params["x"] - prob.x_star))
    assert d1 < 0.3 * d0
    # loss approaches the noise floor 0.5*sigma^2 = 2.0
    assert hist.loss[-1] < 1.5 * 2.0 + 0.5


def test_batch_sizes_grow_monotonically():
    """Paper Lemma 1: E[b_k] grows; our implementation enforces per-
    trainer monotonicity — check it end-to-end."""
    _, inits, streams = _quad_setup()
    _, hist = train_adloco(quad_loss, inits, streams, BASE)
    firsts = [bs[0] for bs in hist.requested_batches]
    assert all(b2 >= b1 for b1, b2 in zip(firsts, firsts[1:]))
    assert firsts[-1] > firsts[0]       # actually grew


def test_pool_contracts_via_merging():
    _, inits, streams = _quad_setup()
    pool, hist = train_adloco(quad_loss, inits, streams, BASE)
    assert hist.pool_size[0] == 3
    assert pool.k < 3                    # at least one merge fired
    assert any(e["kind"] == "merge" for e in pool.comms.log)


def test_no_merge_ablation_keeps_pool():
    _, inits, streams = _quad_setup()
    acfg = dataclasses.replace(BASE, enable_merge=False)
    pool, hist = train_adloco(quad_loss, inits, streams, acfg)
    assert all(k == 3 for k in hist.pool_size)
    # consolidation at the end still yields one model + one comm event
    assert pool.global_params is not None
    assert any(e["kind"] == "consolidate" for e in pool.comms.log)


def test_no_adaptive_ablation_fixed_batch():
    _, inits, streams = _quad_setup()
    acfg = dataclasses.replace(BASE, adaptive=False)
    _, hist = train_adloco(quad_loss, inits, streams, acfg, fixed_batch=4)
    assert all(all(b == 4 for b in bs) for bs in hist.requested_batches[:1])
    # requested batches never updated
    firsts = [bs[0] for bs in hist.requested_batches]
    assert len(set(firsts)) == 1


def test_switch_mode_activates_at_large_batches():
    _, inits, streams = _quad_setup(k=1, M=1)
    acfg = dataclasses.replace(BASE, num_init_trainers=1, nodes_per_gpu=1,
                               max_batch=4, eta=0.3, num_outer_steps=8)
    _, hist = train_adloco(quad_loss, inits[:1], streams[:1], acfg)
    assert any("accum" in m for m in [x for ms in hist.modes for x in ms]), \
        "switch mode never engaged despite tiny max_batch"


def test_switch_off_caps_batch():
    _, inits, streams = _quad_setup(k=1, M=1)
    acfg = dataclasses.replace(BASE, num_init_trainers=1, nodes_per_gpu=1,
                               max_batch=4, eta=0.3, enable_switch=False,
                               num_outer_steps=6)
    _, hist = train_adloco(quad_loss, inits[:1], streams[:1], acfg)
    assert all(m == "plain" for ms in hist.modes for m in ms)


def test_diloco_baseline_runs_and_counts_comms():
    _, inits, streams = _quad_setup(k=1, M=2)
    pool, hist = train_diloco(quad_loss, inits[0], streams[:2], BASE,
                              fixed_batch=8, num_outer_steps=6)
    # one outer sync per outer step exactly (fixed-batch DiLoCo)
    assert pool.comms.events == 6
    assert hist.loss[-1] < hist.loss[0]


def test_local_sgd_baseline_converges():
    prob, inits, streams = _quad_setup(k=1, M=3)
    params, hist = train_local_sgd(
        quad_loss, inits[0], streams[:3], num_rounds=8, inner_steps=5,
        lr=0.05, batch_size=8)
    d1 = float(jnp.linalg.norm(params["x"] - prob.x_star))
    assert d1 < float(jnp.linalg.norm(inits[0]["x"] - prob.x_star))


def test_adloco_fewer_comms_than_diloco_to_target():
    """The paper's headline: communications-to-target shrink.  Uses the
    deterministic expected loss E[f] = 0.5(||x - x*||^2 + sigma^2) as the
    target metric (per-minibatch losses at b=2 are far too noisy)."""
    prob, inits, streams = _quad_setup()
    eval_fn = lambda p: 0.5 * float(  # noqa: E731
        jnp.sum(jnp.square(p["x"] - prob.x_star))) + 0.5 * prob.noise ** 2
    acfg_a = dataclasses.replace(BASE, num_outer_steps=14)
    pool_a, hist_a = train_adloco(quad_loss, inits, streams, acfg_a,
                                  eval_fn=eval_fn)
    _, inits2, streams2 = _quad_setup()
    acfg_d = dataclasses.replace(BASE, adaptive=False, enable_merge=False,
                                 enable_switch=False, num_outer_steps=60)
    pool_d, hist_d = train_diloco(quad_loss, inits2[0], streams2[:2],
                                  acfg_d, fixed_batch=2,
                                  num_outer_steps=60, eval_fn=eval_fn)
    target = 0.5 * prob.noise ** 2 * 1.25     # within 25% of noise floor
    def comms_to_target(hist):
        for loss, ev in zip(hist.eval_loss, hist.comm_events):
            if loss <= target:
                return ev
        return None
    ev_a = comms_to_target(hist_a)
    ev_d = comms_to_target(hist_d)
    assert ev_a is not None, "AdLoCo never reached target"
    if ev_d is not None:
        assert ev_a <= ev_d, (ev_a, ev_d)


def test_communication_complexity_log_growth():
    """Theorem 2's accounting: C(N) = sum_k b_max/b_k over gradient
    (accumulation) iterations.  With the measured batch-growth sequence
    (Theorem 1: b_k = Omega(k)), the partial sums must fit a*ln N + c
    better than a*N + c."""
    _, inits, streams = _quad_setup(k=1, M=1)
    acfg = dataclasses.replace(BASE, num_init_trainers=1, nodes_per_gpu=1,
                               num_outer_steps=25, eta=0.6, lr_inner=0.02,
                               initial_batch_size=1, stats_probe_size=4096,
                               max_global_batch=100_000)
    _, hist = train_adloco(quad_loss, inits[:1], streams[:1], acfg)
    b_max = acfg.max_batch
    # measured per-iteration batch sequence: b of the round, repeated for
    # its H inner iterations
    b_seq = np.concatenate([
        np.full(acfg.num_inner_steps, bs[0], float)
        for bs in hist.requested_batches])
    C = np.cumsum(b_max / np.maximum(b_seq, 1.0))
    N = np.arange(1, len(C) + 1, dtype=float)
    A_log = np.vstack([np.log(N), np.ones_like(N)]).T
    A_lin = np.vstack([N, np.ones_like(N)]).T
    r_log = np.linalg.lstsq(A_log, C, rcond=None)[1]
    r_lin = np.linalg.lstsq(A_lin, C, rcond=None)[1]
    assert float(r_log[0]) < float(r_lin[0]), \
        "C(N) growth looks linear, not logarithmic"
    # and batch growth itself is at least linear-ish (Theorem 1)
    assert b_seq[-1] >= 5 * b_seq[0]


@pytest.mark.slow
def test_adloco_on_tiny_lm():
    """End-to-end on a real (reduced) transformer with the Markov data
    pipeline: loss decreases, adaptive batching engages."""
    cfg = reduced(get_config("microllama-300m"))
    acfg = AdLoCoConfig(num_outer_steps=4, num_inner_steps=4, lr_inner=3e-4,
                        lr_outer=0.5, nodes_per_gpu=2, num_init_trainers=2,
                        initial_batch_size=2, merge_frequency=2,
                        max_batch=8, stats_probe_size=8)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    inits = [models.init_params(cfg, k) for k in keys]
    streams = [MarkovTokenStream(cfg.vocab_size, 32, shard=i, seed=0)
               for i in range(4)]
    loss_fn = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731
    pool, hist = train_adloco(loss_fn, inits, streams, acfg)
    assert hist.loss[-1] < hist.loss[0]
    assert np.isfinite(hist.loss).all()
    assert pool.comms.events > 0


def test_microbatch_estimator_grows_batch_like_per_sample():
    """The free distributed estimator (Var over the M workers' microbatch
    grads) must drive batch growth of the same order as the exact
    per-sample probe on the convex proxy."""
    _, inits, streams = _quad_setup(k=1, M=4)
    base = dataclasses.replace(
        BASE, num_init_trainers=1, nodes_per_gpu=4, num_outer_steps=8,
        initial_batch_size=2, max_global_batch=100_000, max_batch=64)
    acfg_ps = dataclasses.replace(base, stats_estimator="per_sample",
                                  stats_probe_size=4096)
    _, hist_ps = train_adloco(quad_loss, inits[:1], streams[:4], acfg_ps)

    _, inits2, streams2 = _quad_setup(k=1, M=4)
    acfg_mb = dataclasses.replace(base, stats_estimator="microbatch")
    _, hist_mb = train_adloco(quad_loss, inits2[:1], streams2[:4], acfg_mb)

    b_ps = hist_ps.requested_batches[-1][0]
    b_mb = hist_mb.requested_batches[-1][0]
    assert b_mb > 2, "microbatch estimator never grew the batch"
    # same order of magnitude (estimators agree up to sampling noise)
    assert 0.1 < b_mb / max(b_ps, 1) < 10.0, (b_ps, b_mb)
