"""Deterministic (no-hypothesis) tests for the distributed stats
composition protocol — kept out of test_batching.py so its module-level
``pytest.importorskip("hypothesis")`` cannot silently skip the core
composition-law coverage on environments without hypothesis.  The
randomized property tests over the same law live in test_batching.py
and ride along wherever hypothesis is installed (CI pins it)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batching


def _split_shards(G, cuts):
    """Split the row axis at the (sorted, deduped) cut points."""
    edges = sorted({c % (G.shape[0] - 1) + 1 for c in cuts})
    return jnp.split(G, edges, axis=0)


def _assert_stats_close(a, b, rel=5e-3):
    # per-field relative tolerance plus an absolute floor scaled to the
    # largest statistic: the variance fields subtract near-equal f32
    # sums (catastrophic cancellation), so a near-zero orth_var carries
    # error proportional to Σ‖g‖², not to itself
    scale = max(abs(float(v)) for v in a)
    for name, x, y in zip(batching.GradStats._fields, a, b):
        tol = rel * max(abs(float(x)), abs(float(y))) + 1e-5 * scale
        assert abs(float(x) - float(y)) <= tol, (name, float(x), float(y))


def test_sharded_stats_compose_to_concatenated_matrix():
    """The composition law on fixed fixtures: uneven shards and the
    one-row-per-shard (microbatch) edge both reproduce
    stats_from_matrix on the row concatenation."""
    rng = np.random.default_rng(0)
    G = jnp.asarray(rng.standard_normal((13, 23)) * 3 + 1, jnp.float32)
    full = batching.stats_from_matrix(G)
    _assert_stats_close(full, batching.compose_shards(
        [G[:4], G[4:5], G[5:]]))
    _assert_stats_close(full, batching.compose_shards(
        [G[i:i + 1] for i in range(G.shape[0])]))


def test_distributed_stats_identity_reduce_is_single_shard():
    """With the identity SUM reduce (single process) the protocol must
    reproduce stats_from_matrix on the local shard."""
    rng = np.random.default_rng(7)
    G = jnp.asarray(rng.standard_normal((12, 20)), jnp.float32)
    st_ = batching.distributed_stats(G, lambda v: v)
    _assert_stats_close(batching.stats_from_matrix(G), st_, rel=1e-4)


def test_distributed_stats_microbatch_rescale_matches_estimator():
    """micro_size rescale through the protocol == the in-process
    microbatch estimator on the stacked rows."""
    rng = np.random.default_rng(8)
    rows = [jnp.asarray(rng.standard_normal(24), jnp.float32)
            for _ in range(4)]
    stack = {"g": jnp.stack(rows)}
    st_in = batching.stats_from_microbatch_grads(stack, micro_size=8)
    # emulate 4 processes: each contributes one row, reduce = in-process
    # sums over the shard list
    shards = [r[None] for r in rows]
    st_comp = batching.compose_shards(shards, micro_size=8)
    _assert_stats_close(st_in, st_comp, rel=1e-4)


def test_stats_payload_bytes_prices_both_phases():
    """The priced payload is the phase-1 [colsum, count] vector plus
    the five phase-2 scalars: one f32 per parameter plus six — the
    same order as a gradient all-reduce (the runtime must not price
    the stats agreement as free)."""
    assert batching.stats_payload_bytes(16) == 4.0 * (16 + 6)
    assert batching.stats_payload_bytes(0) == 24.0


def test_growth_predictor_warmup_and_exact_exponential_fit():
    """Fewer than two exact observations cannot anchor a fit — the
    predictor must fall back to the current batch — and once the
    observations lie on an exponential the extrapolation is exact."""
    pred = batching.BatchGrowthPredictor(max_global_batch=512)
    assert pred.predict(3, 7) == 7
    pred.observe(1, 4)
    assert pred.predict(3, 7) == 7          # one point is still warmup
    pred.observe(2, 8)
    pred.observe(3, 16)
    # ln b is exactly linear in the round, so the fitted line passes
    # through every future doubling (the 1e-9 guard absorbs float fuzz)
    assert pred.predict(5, 16) == 64
    assert pred.predict(6, 16) == 128


def test_growth_predictor_monotone_capped_and_slope_clamped():
    """Conservatism contract: predictions never shrink the batch, never
    exceed the global cap, and a decreasing observation sequence clamps
    the slope to zero (round-independent prediction) instead of
    extrapolating the batch downward."""
    pred = batching.BatchGrowthPredictor(max_global_batch=64)
    pred.observe(1, 4)
    pred.observe(2, 8)
    assert pred.predict(20, 8) == 64        # capped, not 2 ** 21
    assert pred.predict(3, 60) >= 60        # monotone vs current batch
    down = batching.BatchGrowthPredictor(max_global_batch=64)
    down.observe(1, 16)
    down.observe(2, 8)
    # clamped slope: the fit is flat, so prediction cannot depend on
    # how far ahead the skipped round is
    assert down.predict(3, 8) == down.predict(30, 8)
    assert down.predict(3, 8) >= 8


def test_growth_predictor_ignores_stale_async_observations():
    """Async folds can replay an older round's decision after a newer
    one; the predictor must drop stale/duplicate observations so every
    rank fits the same ordered series."""
    pred = batching.BatchGrowthPredictor(max_global_batch=512)
    pred.observe(4, 32)
    pred.observe(4, 48)                     # duplicate round: dropped
    pred.observe(2, 8)                      # stale round: dropped
    assert pred.num_observations == 1
    pred.observe(7, 64)
    ref = batching.BatchGrowthPredictor(max_global_batch=512)
    ref.observe(4, 32)
    ref.observe(7, 64)
    assert pred.predict(9, 64) == ref.predict(9, 64)


def test_decision_agreement_under_prediction():
    """The k_correct protocol across simulated ranks: correction rounds
    decide once from the composed (all-reduced) shard statistics, and
    the skipped rounds read each rank's *local* predictor — yet every
    rank must derive the identical batch trajectory, with the stats
    composition running only on the corrections."""
    rng = np.random.default_rng(5)
    ranks, T, k_correct, cap = 4, 10, 3, 512
    preds = [batching.BatchGrowthPredictor(cap) for _ in range(ranks)]
    b = [4] * ranks
    traj = [[] for _ in range(ranks)]
    compositions = 0
    for r in range(1, T + 1):
        if (r - 1) % k_correct == 0:
            # exact: one shard per rank, one composition standing in for
            # the all-reduce (its result is identical on every rank)
            shards = [jnp.asarray(rng.standard_normal((3, 16)) * 2.0,
                                  jnp.float32) for _ in range(ranks)]
            st_ = batching.compose_shards(shards)
            compositions += 1
            req = int(batching.norm_test(st_, 0.5))
            for k in range(ranks):
                b[k] = min(max(b[k], req), cap)
                preds[k].observe(r, b[k])
        else:
            for k in range(ranks):
                b[k] = preds[k].predict(r, b[k])
        for k in range(ranks):
            traj[k].append(b[k])
    assert all(t == traj[0] for t in traj)
    corrections = [r for r in range(1, T + 1) if (r - 1) % k_correct == 0]
    assert compositions == len(corrections) < T


def test_periodic_correction_pins_predicted_arm_to_exact():
    """Exact-every-round vs k_correct=3 over the same stats schedule
    (requested batch doubles per round): after the second correction
    anchors the fit, the predicted arm reproduces the exact trajectory
    on every round — including the capped tail — while paying stats
    evaluations only on corrections."""
    eta, cap, T, k_correct = 0.5, 512, 9, 3

    def stats_at(r):
        # eq-10 ratio = sigma2 / (eta^2 * mean_norm2) = 9 * 2^(r-1)
        return batching.GradStats(
            mean_norm2=jnp.float32(4.0 / 2 ** (r - 1)),
            sigma2=jnp.float32(9.0), ip_var=jnp.float32(0.0),
            orth_var=jnp.float32(0.0), b=jnp.float32(8))

    exact, pred_arm = 4, 4
    pred = batching.BatchGrowthPredictor(cap)
    evals = 0
    exact_traj, pred_traj = [], []
    for r in range(1, T + 1):
        exact = min(max(exact, int(batching.norm_test(stats_at(r), eta))),
                    cap)
        if (r - 1) % k_correct == 0:
            evals += 1
            pred_arm = min(max(pred_arm,
                               int(batching.norm_test(stats_at(r), eta))),
                           cap)
            pred.observe(r, pred_arm)
        else:
            pred_arm = pred.predict(r, pred_arm)
        exact_traj.append(exact)
        pred_traj.append(pred_arm)
    corrections = [r for r in range(1, T + 1) if (r - 1) % k_correct == 0]
    for r in corrections:
        assert pred_traj[r - 1] == exact_traj[r - 1]
    # once two corrections anchor the fit, parity is per-round exact
    second = corrections[1]
    assert pred_traj[second - 1:] == exact_traj[second - 1:]
    assert exact_traj[-1] == cap            # the schedule reaches the cap
    assert evals == len(corrections) < T


def test_batch_tests_stable_at_integer_ratios():
    """The epsilon-guarded ceil: statistics whose test ratio lands
    exactly on an integer must request exactly that integer, and a
    sub-ulp perturbation (the in-process vs two-phase route noise)
    must not flip the decision."""
    st_ = batching.GradStats(
        mean_norm2=jnp.float32(4.0), sigma2=jnp.float32(9.0),
        ip_var=jnp.float32(0.0), orth_var=jnp.float32(0.0),
        b=jnp.float32(8))
    # eq 10 with eta=0.5: the exact ratio is 9.0
    assert int(batching.norm_test(st_, 0.5)) == 9
    bumped = st_._replace(sigma2=jnp.float32(np.nextafter(
        np.float32(9.0), np.float32(10.0))))
    assert int(batching.norm_test(bumped, 0.5)) == 9
