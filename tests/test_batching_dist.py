"""Deterministic (no-hypothesis) tests for the distributed stats
composition protocol — kept out of test_batching.py so its module-level
``pytest.importorskip("hypothesis")`` cannot silently skip the core
composition-law coverage on environments without hypothesis.  The
randomized property tests over the same law live in test_batching.py
and ride along wherever hypothesis is installed (CI pins it)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batching


def _split_shards(G, cuts):
    """Split the row axis at the (sorted, deduped) cut points."""
    edges = sorted({c % (G.shape[0] - 1) + 1 for c in cuts})
    return jnp.split(G, edges, axis=0)


def _assert_stats_close(a, b, rel=5e-3):
    # per-field relative tolerance plus an absolute floor scaled to the
    # largest statistic: the variance fields subtract near-equal f32
    # sums (catastrophic cancellation), so a near-zero orth_var carries
    # error proportional to Σ‖g‖², not to itself
    scale = max(abs(float(v)) for v in a)
    for name, x, y in zip(batching.GradStats._fields, a, b):
        tol = rel * max(abs(float(x)), abs(float(y))) + 1e-5 * scale
        assert abs(float(x) - float(y)) <= tol, (name, float(x), float(y))


def test_sharded_stats_compose_to_concatenated_matrix():
    """The composition law on fixed fixtures: uneven shards and the
    one-row-per-shard (microbatch) edge both reproduce
    stats_from_matrix on the row concatenation."""
    rng = np.random.default_rng(0)
    G = jnp.asarray(rng.standard_normal((13, 23)) * 3 + 1, jnp.float32)
    full = batching.stats_from_matrix(G)
    _assert_stats_close(full, batching.compose_shards(
        [G[:4], G[4:5], G[5:]]))
    _assert_stats_close(full, batching.compose_shards(
        [G[i:i + 1] for i in range(G.shape[0])]))


def test_distributed_stats_identity_reduce_is_single_shard():
    """With the identity SUM reduce (single process) the protocol must
    reproduce stats_from_matrix on the local shard."""
    rng = np.random.default_rng(7)
    G = jnp.asarray(rng.standard_normal((12, 20)), jnp.float32)
    st_ = batching.distributed_stats(G, lambda v: v)
    _assert_stats_close(batching.stats_from_matrix(G), st_, rel=1e-4)


def test_distributed_stats_microbatch_rescale_matches_estimator():
    """micro_size rescale through the protocol == the in-process
    microbatch estimator on the stacked rows."""
    rng = np.random.default_rng(8)
    rows = [jnp.asarray(rng.standard_normal(24), jnp.float32)
            for _ in range(4)]
    stack = {"g": jnp.stack(rows)}
    st_in = batching.stats_from_microbatch_grads(stack, micro_size=8)
    # emulate 4 processes: each contributes one row, reduce = in-process
    # sums over the shard list
    shards = [r[None] for r in rows]
    st_comp = batching.compose_shards(shards, micro_size=8)
    _assert_stats_close(st_in, st_comp, rel=1e-4)


def test_stats_payload_bytes_prices_both_phases():
    """The priced payload is the phase-1 [colsum, count] vector plus
    the five phase-2 scalars: one f32 per parameter plus six — the
    same order as a gradient all-reduce (the runtime must not price
    the stats agreement as free)."""
    assert batching.stats_payload_bytes(16) == 4.0 * (16 + 6)
    assert batching.stats_payload_bytes(0) == 24.0


def test_batch_tests_stable_at_integer_ratios():
    """The epsilon-guarded ceil: statistics whose test ratio lands
    exactly on an integer must request exactly that integer, and a
    sub-ulp perturbation (the in-process vs two-phase route noise)
    must not flip the decision."""
    st_ = batching.GradStats(
        mean_norm2=jnp.float32(4.0), sigma2=jnp.float32(9.0),
        ip_var=jnp.float32(0.0), orth_var=jnp.float32(0.0),
        b=jnp.float32(8))
    # eq 10 with eta=0.5: the exact ratio is 9.0
    assert int(batching.norm_test(st_, 0.5)) == 9
    bumped = st_._replace(sigma2=jnp.float32(np.nextafter(
        np.float32(9.0), np.float32(10.0))))
    assert int(batching.norm_test(bumped, 0.5)) == 9
