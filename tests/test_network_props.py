"""Property tests for the network cost models.

The invariants that make topology-aware routing safe to use
unconditionally: the two-level hierarchical schedule never loses to the
flat ring when the cross-pod bottleneck is at least as good as a node
link, it is monotone in payload, cheaper cross-pod links never hurt,
and a single pod collapses exactly to the ring model.
"""
import pytest

# property tests ride along whenever hypothesis is installed (CI
# installs it; the bare jax image can still run the rest of the suite)
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.comms import (hierarchical_allreduce_time,  # noqa: E402
                              ring_allreduce_time)

bws = st.floats(min_value=1e-6, max_value=1e12)
payloads = st.floats(min_value=1.0, max_value=1e12)
lats = st.floats(min_value=0.0, max_value=1.0)


@given(payload=payloads, pods=st.integers(2, 8), per_pod=st.integers(1, 8),
       bw=bws, boost=st.floats(1.0, 1e4), lat=lats,
       lat_frac=st.floats(0.0, 1.0))
@settings(max_examples=200, deadline=None)
def test_hierarchical_never_loses_to_flat_ring(payload, pods, per_pod, bw,
                                               boost, lat, lat_frac):
    """With equal-size pods, cross-pod bandwidth >= the per-node link
    bandwidth and cross-pod latency no worse than a hop, the two-level
    schedule is at most the flat ring over all nodes (equality when the
    bottleneck is exactly a node link)."""
    n = pods * per_pod
    flat = ring_allreduce_time(payload, n, bw, lat)
    hier = hierarchical_allreduce_time(
        payload, [per_pod] * pods, bw, bw * boost,
        intra_latency=lat, inter_latency=lat * lat_frac)
    assert hier <= flat * (1 + 1e-9) + 1e-12


@given(a=payloads, b=payloads, pods=st.lists(st.integers(1, 8), min_size=1,
                                             max_size=6),
       intra=bws, inter=bws, lat_i=lats, lat_x=lats)
@settings(max_examples=200, deadline=None)
def test_hierarchical_monotone_in_payload(a, b, pods, intra, inter,
                                          lat_i, lat_x):
    lo, hi = min(a, b), max(a, b)
    t_lo = hierarchical_allreduce_time(lo, pods, intra, inter,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    t_hi = hierarchical_allreduce_time(hi, pods, intra, inter,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    assert t_lo <= t_hi * (1 + 1e-9)


@given(payload=payloads, p=st.integers(1, 64), bw=bws, lat=lats,
       inter=bws, lat_x=lats)
@settings(max_examples=200, deadline=None)
def test_single_pod_reduces_to_ring(payload, p, bw, lat, inter, lat_x):
    """One pod: the cross-pod terms vanish and the result is exactly
    the flat ring (bit-for-bit, so Topology pricing of an intra-pod
    collective agrees with NetworkModel)."""
    assert hierarchical_allreduce_time(
        payload, [p], bw, inter, intra_latency=lat,
        inter_latency=lat_x) == ring_allreduce_time(payload, p, bw, lat)


@given(payload=payloads, pods=st.lists(st.integers(1, 8), min_size=2,
                                       max_size=6),
       intra=bws, inter=bws, boost=st.floats(1.0, 1e4), lat_i=lats,
       lat_x=lats)
@settings(max_examples=200, deadline=None)
def test_more_cross_pod_bandwidth_never_hurts(payload, pods, intra, inter,
                                              boost, lat_i, lat_x):
    slow = hierarchical_allreduce_time(payload, pods, intra, inter,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    fast = hierarchical_allreduce_time(payload, pods, intra, inter * boost,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    assert fast <= slow * (1 + 1e-9)
