"""Property tests for the network cost models.

The invariants that make topology-aware routing safe to use
unconditionally: the hierarchical schedule — at any depth — never loses
to the flat ring when every level's paths are at least as good as a
node link, it is monotone in payload and in every level's latency,
cheaper cross-pod links never hurt, a single pod collapses exactly to
the ring model, and the cost of a collective depends only on *which*
nodes participate, never on the order they are listed in.
"""
import pytest

# property tests ride along whenever hypothesis is installed (CI
# installs it; the bare jax image can still run the rest of the suite)
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.comms import (CommDomain,  # noqa: E402
                              hierarchical_allreduce_time,
                              ring_allreduce_time)
from repro.cluster import (Topology, make_rack_profiles)  # noqa: E402

bws = st.floats(min_value=1e-6, max_value=1e12)
payloads = st.floats(min_value=1.0, max_value=1e12)
lats = st.floats(min_value=0.0, max_value=1.0)


@given(payload=payloads, pods=st.integers(2, 8), per_pod=st.integers(1, 8),
       bw=bws, boost=st.floats(1.0, 1e4), lat=lats,
       lat_frac=st.floats(0.0, 1.0))
@settings(max_examples=200, deadline=None)
def test_hierarchical_never_loses_to_flat_ring(payload, pods, per_pod, bw,
                                               boost, lat, lat_frac):
    """With equal-size pods, cross-pod bandwidth >= the per-node link
    bandwidth and cross-pod latency no worse than a hop, the two-level
    schedule is at most the flat ring over all nodes (equality when the
    bottleneck is exactly a node link)."""
    n = pods * per_pod
    flat = ring_allreduce_time(payload, n, bw, lat)
    hier = hierarchical_allreduce_time(
        payload, [per_pod] * pods, bw, bw * boost,
        intra_latency=lat, inter_latency=lat * lat_frac)
    assert hier <= flat * (1 + 1e-9) + 1e-12


@given(a=payloads, b=payloads, pods=st.lists(st.integers(1, 8), min_size=1,
                                             max_size=6),
       intra=bws, inter=bws, lat_i=lats, lat_x=lats)
@settings(max_examples=200, deadline=None)
def test_hierarchical_monotone_in_payload(a, b, pods, intra, inter,
                                          lat_i, lat_x):
    lo, hi = min(a, b), max(a, b)
    t_lo = hierarchical_allreduce_time(lo, pods, intra, inter,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    t_hi = hierarchical_allreduce_time(hi, pods, intra, inter,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    assert t_lo <= t_hi * (1 + 1e-9)


@given(payload=payloads, p=st.integers(1, 64), bw=bws, lat=lats,
       inter=bws, lat_x=lats)
@settings(max_examples=200, deadline=None)
def test_single_pod_reduces_to_ring(payload, p, bw, lat, inter, lat_x):
    """One pod: the cross-pod terms vanish and the result is exactly
    the flat ring (bit-for-bit, so Topology pricing of an intra-pod
    collective agrees with NetworkModel)."""
    assert hierarchical_allreduce_time(
        payload, [p], bw, inter, intra_latency=lat,
        inter_latency=lat_x) == ring_allreduce_time(payload, p, bw, lat)


@given(payload=payloads, pods=st.lists(st.integers(1, 8), min_size=2,
                                       max_size=6),
       intra=bws, inter=bws, boost=st.floats(1.0, 1e4), lat_i=lats,
       lat_x=lats)
@settings(max_examples=200, deadline=None)
def test_more_cross_pod_bandwidth_never_hurts(payload, pods, intra, inter,
                                              boost, lat_i, lat_x):
    slow = hierarchical_allreduce_time(payload, pods, intra, inter,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    fast = hierarchical_allreduce_time(payload, pods, intra, inter * boost,
                                       intra_latency=lat_i,
                                       inter_latency=lat_x)
    assert fast <= slow * (1 + 1e-9)


# --------------------------------------------------- n-level invariants

#: random balanced level stacks: (leaf_size, [branching per level])
stacks = st.tuples(st.integers(1, 4),
                   st.lists(st.integers(2, 4), min_size=1, max_size=3))


def _stack_tree(leaf_size, branches, bw, lat, boosts, lat_fracs):
    """Balanced tree bottom-up: every level's paths run at bw*boost
    (>= bw) with latency lat*frac (<= lat)."""
    dom = CommDomain(bw=bw, latency=lat, size=leaf_size)
    for k, boost, frac in zip(branches, boosts, lat_fracs):
        dom = CommDomain(bw=bw * boost, latency=lat * frac,
                         children=(dom,) * k)
    return dom


@given(payload=payloads, stack=stacks, bw=bws, lat=lats,
       boosts=st.lists(st.floats(1.0, 1e4), min_size=3, max_size=3),
       fracs=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3))
@settings(max_examples=200, deadline=None)
def test_deeper_hierarchy_never_loses_to_flat_ring(payload, stack, bw, lat,
                                                   boosts, fracs):
    """At any depth: with every level's per-path bandwidth >= the leaf
    link bandwidth and per-hop latency no worse than a leaf hop, the
    level schedule is at most the flat ring over all nodes."""
    leaf_size, branches = stack
    root = _stack_tree(leaf_size, branches, bw, lat, boosts, fracs)
    n = leaf_size
    for k in branches:
        n *= k
    flat = ring_allreduce_time(payload, n, bw, lat)
    hier = hierarchical_allreduce_time(payload, root)
    assert hier <= flat * (1 + 1e-9) + 1e-12


#: recursive random (possibly lopsided) domain-tree *specs* — plain
#: data so a test can rebuild the same tree with one knob changed
leaf_specs = st.tuples(st.just("leaf"), st.integers(0, 5), bws, lats)
tree_specs = st.recursive(
    leaf_specs,
    lambda sub: st.tuples(st.just("node"), bws, lats,
                          st.lists(sub, min_size=1, max_size=3)),
    max_leaves=12)


def _spec_height(spec):
    if spec[0] == "leaf":
        return 0
    return 1 + max(_spec_height(c) for c in spec[3])


def _spec_tree(spec, bump_height=None, delta=0.0):
    """Build the CommDomain, adding ``delta`` latency to every domain
    at height ``bump_height`` (None: build as-is)."""
    h = _spec_height(spec)
    extra = delta if h == bump_height else 0.0
    if spec[0] == "leaf":
        return CommDomain(bw=spec[2], latency=spec[3] + extra,
                          size=spec[1])
    return CommDomain(bw=spec[1], latency=spec[2] + extra,
                      children=tuple(_spec_tree(c, bump_height, delta)
                                     for c in spec[3]))


@given(a=payloads, b=payloads, spec=tree_specs)
@settings(max_examples=200, deadline=None)
def test_tree_cost_monotone_in_payload(a, b, spec):
    lo, hi = min(a, b), max(a, b)
    t_lo = hierarchical_allreduce_time(lo, _spec_tree(spec))
    t_hi = hierarchical_allreduce_time(hi, _spec_tree(spec))
    assert t_lo <= t_hi * (1 + 1e-9)


@given(payload=payloads, spec=tree_specs, level=st.integers(0, 4),
       delta=st.floats(0.0, 1.0))
@settings(max_examples=200, deadline=None)
def test_tree_cost_monotone_in_per_level_latency(payload, spec, level,
                                                 delta):
    """Slower hops at any one level never make the collective cheaper."""
    base = hierarchical_allreduce_time(payload, _spec_tree(spec))
    bumped = hierarchical_allreduce_time(
        payload, _spec_tree(spec, bump_height=level, delta=delta))
    assert base <= bumped * (1 + 1e-9) + 1e-12


TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)


@given(perm=st.permutations(list(range(8))),
       size=st.integers(2, 8), payload=payloads)
@settings(max_examples=100, deadline=None)
def test_participant_permutation_leaves_cost_unchanged(perm, size,
                                                       payload):
    """Topology pricing is a function of *which* nodes participate:
    permuting the participant list — including nodes within one domain —
    changes nothing, bit for bit."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    for i, p in enumerate(profiles):     # heterogeneous inside racks too
        p.link_bw *= 1.0 + i / 7.0
        p.link_latency *= 1.0 + (7 - i) / 7.0
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    chosen = [profiles[i] for i in perm[:size]]
    shuffled = [profiles[i] for i in sorted(perm[:size])]
    assert topo.allreduce_time(payload, chosen) == \
        topo.allreduce_time(payload, shuffled)
