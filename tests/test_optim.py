"""Optimizer unit tests against closed forms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests ride along whenever hypothesis is installed (CI
# pins it); without it the whole module is skipped rather than
# erroring at collection
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import optim


def _step(opt, params, grads, state=None):
    state = opt.init(params) if state is None else state
    upd, state = opt.update(grads, state, params)
    return optim.apply_updates(params, upd), state


def test_sgd_plain_closed_form():
    opt = optim.sgd(0.1)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([10.0, -10.0])}
    p1, _ = _step(opt, p, g)
    np.testing.assert_allclose(p1["w"], [0.0, 3.0], atol=1e-6)


def test_sgd_momentum_accumulates():
    opt = optim.sgd(1.0, momentum=0.5)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    p, st_ = _step(opt, p, g)
    np.testing.assert_allclose(p["w"], [-1.0])       # m=1
    p, st_ = _step(opt, p, g, st_)
    np.testing.assert_allclose(p["w"], [-2.5])       # m=1.5


def test_nesterov_lookahead():
    opt = optim.nesterov_outer(1.0, momentum=0.5)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    p, st_ = _step(opt, p, g)
    # m=1; update = -(0.5*1 + 1) = -1.5
    np.testing.assert_allclose(p["w"], [-1.5])


def test_adamw_first_step_is_lr_sized():
    """After one step from zero state, |update| ~= lr regardless of
    gradient scale (bias-corrected)."""
    opt = optim.adamw(1e-2)
    for scale in (1e-3, 1.0, 1e3):
        p = {"w": jnp.zeros(3)}
        g = {"w": jnp.full((3,), scale)}
        p1, _ = _step(opt, p, g)
        np.testing.assert_allclose(p1["w"], -1e-2 * np.ones(3), rtol=1e-3)


def test_adamw_weight_decay_decoupled():
    opt = optim.adamw(0.1, weight_decay=0.5)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.0])}
    p1, _ = _step(opt, p, g)
    # zero grad -> pure decay: p - lr*wd*p = 2 - 0.1*0.5*2
    np.testing.assert_allclose(p1["w"], [1.9], atol=1e-6)


def test_adagrad_closed_form():
    opt = optim.adagrad(1.0)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.asarray([2.0])}
    p, st_ = _step(opt, p, g)
    np.testing.assert_allclose(p["w"], [-1.0], atol=1e-5)   # g/sqrt(g^2)
    p, st_ = _step(opt, p, g, st_)
    np.testing.assert_allclose(p["w"], [-1.0 - 2.0 / np.sqrt(8.0)],
                               atol=1e-5)


def test_bf16_params_keep_f32_state():
    opt = optim.adamw(1e-3)
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st_ = opt.init(p)
    assert st_["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    upd, st_ = opt.update(g, st_, p)
    p2 = optim.apply_updates(p, upd)
    assert p2["w"].dtype == jnp.bfloat16


@settings(max_examples=15, deadline=None)
@given(st.floats(1e-4, 0.5), st.integers(1, 5))
def test_property_sgd_descends_quadratic(lr, steps):
    """SGD on f(w) = 0.5 w^2 never increases f for lr < 1."""
    opt = optim.sgd(lr)
    w = jnp.asarray([1.0])
    st_ = opt.init({"w": w})
    f = lambda w: 0.5 * float(w[0]) ** 2  # noqa: E731
    prev = f(w)
    p = {"w": w}
    for _ in range(steps):
        g = {"w": p["w"]}
        p, st_ = _step(opt, p, g, st_)
        cur = f(p["w"])
        assert cur <= prev + 1e-9
        prev = cur


def test_get_optimizer_registry():
    for name in ("sgd", "adamw", "adagrad", "nesterov"):
        opt = optim.get_optimizer(name, 1e-3)
        assert isinstance(opt, optim.Optimizer)
    with pytest.raises(KeyError):
        optim.get_optimizer("lion", 1e-3)
