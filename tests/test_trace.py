"""Tests for the structured trace layer (``repro.cluster.trace``).

Three tiers of pinning:

* **Golden trace digests** — the full sim-span schema (every compute /
  collective / stats / transfer / fabric span plus instant annotations)
  for ``adaptive_ramp`` and ``correlated_pod_failure`` is digest-pinned
  in ``tests/goldens/traces.json``, and a complete Perfetto export of
  the ``adaptive_ramp`` trace is committed at
  ``tests/goldens/adaptive_ramp.perfetto.json`` — it must validate and
  round-trip digest-identically.  Regenerate both with
  ``--update-goldens`` (same switch as the scenario goldens).
* **Ledger partition property** — for randomized scenarios (scripted
  slowdowns, leaves, joins, fabric windows at fuzzed times),
  ``busy + blocked + idle == alive`` holds exactly for every trainer;
  runs under hypothesis when installed, over a fixed seed sweep
  otherwise.
* **Invariants** — sync's overlap fraction is exactly 0.0 and async's
  strictly positive on the same fixture; tracing never perturbs
  scheduling (summary with and without a trace attached is identical);
  the default ``ClusterReport.summary()`` is byte-identical with the
  extended fields opt-in only.
"""
import json
import pathlib

import pytest

from repro.cluster import ClusterEvent, Trace, run_cluster, validate_perfetto
from repro.cluster.trace import (_clip, _overlap_total, _subtract, _total,
                                 _union)

from tests.test_adloco_integration import QuadStream, _quad_setup, quad_loss
from tests.test_scenarios import (ACFG, ACFG_ADAPTIVE, TOY, UPDATE_CMD,
                                  _tree_cluster)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # bare jax image: seed sweep instead
    HAVE_HYPOTHESIS = False

GOLDENS_PATH = pathlib.Path(__file__).parent / "goldens" / "traces.json"
PERFETTO_GOLDEN = (pathlib.Path(__file__).parent / "goldens"
                   / "adaptive_ramp.perfetto.json")


# ------------------------------------------------------------ harnesses

def _run_adaptive_traced(name):
    """The test_scenarios adaptive harness with a trace attached."""
    from repro.cluster import (Topology, interleave_pods,
                               make_pod_profiles)
    profiles = make_pod_profiles([5, 5], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    tr = Trace()
    out = run_cluster(quad_loss, inits, streams, ACFG_ADAPTIVE,
                      policy="async", profiles=interleaved, network=topo,
                      scenario=name, trace=tr)
    return tr, out


def _run3_traced(name):
    """The test_scenarios 3-level elastic harness with a trace."""
    interleaved, topo = _tree_cluster()
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(2)]
    tr = Trace()
    out = run_cluster(quad_loss, inits, streams, ACFG, policy="elastic",
                      profiles=interleaved, network=topo, scenario=name,
                      fixed_batch=4, trace=tr)
    return tr, out


_TRACED = {"adaptive_ramp": _run_adaptive_traced,
           "correlated_pod_failure": _run3_traced}

_MEMO = {}


def _memo(name):
    if name not in _MEMO:
        _MEMO[name] = _TRACED[name](name)
    return _MEMO[name]


# ------------------------------------------------------- golden digests

@pytest.mark.parametrize("name", sorted(_TRACED))
def test_trace_digest_matches_golden(name, request):
    tr, _ = _memo(name)
    digest = tr.sim_digest()
    stored = json.loads(GOLDENS_PATH.read_text())
    golden = stored.get(name)
    if digest == golden:
        return
    if request.config.getoption("--update-goldens"):
        stored[name] = digest
        GOLDENS_PATH.write_text(json.dumps(stored, indent=2,
                                           sort_keys=True) + "\n")
        pytest.skip(f"trace golden for {name!r} updated: "
                    f"{golden} -> {digest}; commit "
                    f"tests/goldens/traces.json")
    pytest.fail(
        f"scenario {name!r} produced a different span trace\n"
        f"  stored digest:   {golden}\n"
        f"  current digest:  {digest}\n"
        f"If the schedule/span-schema change is intended, regenerate "
        f"with:\n  {UPDATE_CMD.replace('test_scenarios', 'test_trace')}\n"
        f"and commit the tests/goldens/traces.json diff.")


def test_committed_perfetto_golden_validates_and_round_trips(request):
    """The committed Perfetto export is the schema's integration test:
    it must pass ``trace_report --validate`` and rebuild into a Trace
    whose sim digest matches the live ``adaptive_ramp`` run."""
    tr, _ = _memo("adaptive_ramp")
    if request.config.getoption("--update-goldens"):
        PERFETTO_GOLDEN.write_text(
            json.dumps(tr.to_perfetto(), indent=1, sort_keys=True) + "\n")
    data = json.loads(PERFETTO_GOLDEN.read_text())
    assert validate_perfetto(data) == []
    rebuilt = Trace.from_perfetto(data)
    assert rebuilt.sim_digest() == tr.sim_digest()
    # and the rebuild is lossless: exporting again reproduces the file
    assert json.loads(json.dumps(rebuilt.to_perfetto(),
                                 sort_keys=True)) == data


def test_trace_report_cli_on_committed_golden(tmp_path, capsys):
    from repro.cluster.trace_report import main
    assert main(["--validate", str(PERFETTO_GOLDEN)]) == 0
    assert "schema OK" in capsys.readouterr().out
    assert main([str(PERFETTO_GOLDEN)]) == 0
    out = capsys.readouterr().out
    assert "overlap_frac=" in out and "utilization=" in out
    # corrupted file -> nonzero exit
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [{"ph": "X"}]}')
    assert main(["--validate", str(bad)]) == 1


# ------------------------------------------- ledger partition property

def _random_scenario(rng, n_nodes):
    """Scripted chaos at fuzzed times: slowdowns, a leave, a join, and
    fabric windows (some of which re-price in-flight collectives)."""
    events = []
    for _ in range(rng.integers(0, 4)):
        events.append(ClusterEvent(
            time=float(rng.uniform(0.0, 0.2)), kind="slowdown",
            node=int(rng.integers(0, n_nodes)),
            factor=float(rng.uniform(1.5, 6.0)),
            duration=float(rng.uniform(0.01, 0.2))))
    for _ in range(rng.integers(0, 3)):
        events.append(ClusterEvent(
            time=float(rng.uniform(0.0, 0.2)), kind="fabric",
            bw_scale=float(rng.uniform(0.05, 0.8)),
            extra_latency=float(rng.uniform(0.0, 0.01)),
            duration=float(rng.uniform(0.02, 0.15))))
    if rng.random() < 0.5:
        events.append(ClusterEvent(time=float(rng.uniform(0.02, 0.1)),
                                   kind="leave"))
    if rng.random() < 0.5:
        events.append(ClusterEvent(time=float(rng.uniform(0.05, 0.2)),
                                   kind="join"))
    return sorted(events, key=lambda e: e.time)


def _check_partition(seed):
    import dataclasses

    import numpy as np

    from repro.cluster import make_heterogeneous_profiles
    rng = np.random.default_rng(seed)
    spare = 2
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i)
                         for i in range(spare * 2)]
    n_nodes = 6 + spare * 2
    profiles = make_heterogeneous_profiles(
        n_nodes, ratio=float(rng.uniform(1.0, 4.0)), **TOY)
    acfg = dataclasses.replace(ACFG, num_outer_steps=6)
    tr = Trace()
    _, _, rep = run_cluster(
        quad_loss, inits, streams, acfg,
        policy=str(rng.choice(["sync", "async", "elastic"])),
        profiles=profiles, scenario=_random_scenario(rng, n_nodes),
        fixed_batch=4, trace=tr)
    ledger = tr.utilization()        # raises AssertionError on violation
    assert set(ledger) == set(tr.alive)
    for tid, led in ledger.items():
        assert led["alive"] >= 0.0
        assert led["busy"] >= 0.0 and led["blocked"] >= 0.0 \
            and led["idle"] >= 0.0
        assert (led["busy"] + led["blocked"] + led["idle"]
                == pytest.approx(led["alive"], rel=1e-9, abs=1e-12))


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=15, deadline=None)
    def test_ledger_partitions_every_alive_span(seed):
        _check_partition(seed)
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_ledger_partitions_every_alive_span(seed):
        _check_partition(seed)


# ------------------------------------------------------------ invariants

def test_sync_overlap_is_zero_async_positive():
    """The ROADMAP item-1 metric's calibration: sync is a barrier, so
    no collective can coincide with compute on the same trainer; async
    launches the collective and immediately starts the next round."""
    from repro.cluster import make_heterogeneous_profiles
    fracs = {}
    for policy in ("sync", "async"):
        prob, inits, streams = _quad_setup(k=3, M=2)
        profiles = make_heterogeneous_profiles(6, ratio=2.0, **TOY)
        tr = Trace()
        run_cluster(quad_loss, inits, streams, ACFG, policy=policy,
                    profiles=profiles, fixed_batch=4, trace=tr)
        fracs[policy] = tr.overlap_fraction()
    assert fracs["sync"] == 0.0
    assert fracs["async"] > 0.0


def test_tracing_does_not_perturb_scheduling():
    """trace=None and trace=Trace() must produce identical reports —
    recording is observation, never participation."""
    from repro.cluster import make_heterogeneous_profiles
    reps = []
    for trace in (None, Trace()):
        prob, inits, streams = _quad_setup(k=3, M=2)
        profiles = make_heterogeneous_profiles(6, ratio=2.0, **TOY)
        _, _, rep = run_cluster(quad_loss, inits, streams, ACFG,
                                policy="async", profiles=profiles,
                                fixed_batch=4, trace=trace)
        reps.append(rep)
    assert reps[0].summary() == reps[1].summary()
    assert reps[0].applied_events == reps[1].applied_events
    assert reps[0].trace is None and reps[1].trace is not None


def test_extended_summary_is_opt_in():
    """satellite 1: the default summary dict is untouched (the golden
    digests depend on it); extended=True adds the new fields."""
    tr, (_, _, rep) = _memo("adaptive_ramp")
    default = rep.summary()
    assert set(default) == {"policy", "sim_time", "compute_time",
                            "comm_time", "num_syncs", "rounds"}
    ext = rep.summary(extended=True)
    # the shared keys are byte-identical...
    assert {k: ext[k] for k in default} == default
    # ...and the opt-in tier carries the wire/stats/trace metrics
    assert ext["num_stats_syncs"] == rep.num_stats_syncs
    assert ext["real_comm_time"] == rep.real_comm_time
    assert ext["overlap_frac"] == tr.overlap_fraction()
    assert 0.0 <= ext["utilization"] <= 1.0
    assert ext["utilization"] + ext["blocked_frac"] + ext["idle_frac"] \
        == pytest.approx(1.0)


def test_run_cluster_accepts_trace_true():
    """``trace=True`` is sugar for a fresh Trace (the launch_mp path)."""
    from repro.cluster import make_heterogeneous_profiles
    prob, inits, streams = _quad_setup(k=3, M=2)
    profiles = make_heterogeneous_profiles(6, ratio=2.0, **TOY)
    _, _, rep = run_cluster(quad_loss, inits, streams, ACFG,
                            policy="sync", profiles=profiles,
                            fixed_batch=4, trace=True)
    assert isinstance(rep.trace, Trace)
    assert rep.trace.sim_spans(("compute",))


def test_xfer_reprice_annotation_in_trace():
    """The satellite-2 fix end-to-end: a join transfer crossing a
    fabric window edge leaves the join record at its launch price and
    lands the re-price as an instant + an extended xfer span."""
    import dataclasses

    from repro.cluster import (NetworkModel, make_heterogeneous_profiles)
    from repro.cluster.scenarios import build_scenario
    join_t, window_t = 0.02, 0.025
    scen = (build_scenario("flash_crowd_join", start=join_t, joins=1)
            + [ClusterEvent(time=window_t, kind="fabric", bw_scale=1e-3,
                            extra_latency=0.05, duration=0.0)])
    acfg = dataclasses.replace(ACFG, num_outer_steps=12)
    toy = dict(TOY, link_bw=6e3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(2)]
    profiles = make_heterogeneous_profiles(8, **toy)
    tr = Trace()
    _, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                            policy="elastic", profiles=profiles,
                            network=NetworkModel(), scenario=scen,
                            fixed_batch=4, trace=tr)
    rp = next(e for e in rep.applied_events if e["kind"] == "xfer_reprice")
    xfer = next(s for s in tr.sim_spans(("xfer",)))
    assert xfer.t0 == join_t
    assert xfer.t1 - xfer.t0 == pytest.approx(rp["xfer_s"], rel=1e-12)
    inst = next(e for e in tr.events
                if e.kind == "reprice" and e.payload["target"] == "xfer")
    assert inst.t == window_t


# --------------------------------------------------- interval arithmetic

def test_interval_helpers():
    assert _union([(3, 4), (0, 1), (0.5, 2)]) == [(0, 2), (3, 4)]
    assert _union([(0, 0), (1, 1)]) == []     # empty intervals dropped
    assert _clip([(0, 2), (3, 4)], 1, 3.5) == [(1, 2), (3, 3.5)]
    assert _total([(0, 2), (3, 4)]) == 3
    assert _subtract([(0, 10)], [(2, 3), (5, 7)]) \
        == [(0, 2), (3, 5), (7, 10)]
    assert _subtract([(0, 5)], [(0, 5)]) == []
    assert _subtract([(0, 5)], []) == [(0, 5)]
    assert _overlap_total((1, 4), [(0, 2), (3, 10)]) == 2
    assert _overlap_total((5, 6), [(0, 2)]) == 0
