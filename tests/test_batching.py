"""Unit + property tests for the adaptive batching tests (paper eqs
10/12/13) and their statistics estimators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests ride along whenever hypothesis is installed (CI pins
# it); without it the whole module is skipped rather than erroring at
# collection
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import AdLoCoConfig
from repro.core import batching


def _manual_stats(G):
    """Straight-from-the-paper reference (numpy, explicit loops in math)."""
    G = np.asarray(G, np.float64)
    b, D = G.shape
    gbar = G.mean(0)
    n2 = float(gbar @ gbar)
    sigma2 = float(np.sum((G - gbar) ** 2) / max(b - 1, 1))
    d = G @ gbar
    ip_var = float(np.sum((d - n2) ** 2) / max(b - 1, 1))
    orth = G - np.outer(d / max(n2, 1e-30), gbar)
    orth_var = float(np.sum(orth ** 2) / max(b - 1, 1))
    return n2, sigma2, ip_var, orth_var


def test_stats_match_manual():
    rng = np.random.default_rng(0)
    G = rng.standard_normal((24, 64)) + 0.5
    st_ = batching.stats_from_matrix(jnp.asarray(G, jnp.float32))
    n2, sigma2, ip_var, orth_var = _manual_stats(G)
    assert np.isclose(float(st_.mean_norm2), n2, rtol=1e-4)
    assert np.isclose(float(st_.sigma2), sigma2, rtol=1e-4)
    assert np.isclose(float(st_.ip_var), ip_var, rtol=1e-3)
    assert np.isclose(float(st_.orth_var), orth_var, rtol=1e-3)


def test_norm_test_closed_form():
    """σ² and ‖ḡ‖² chosen exactly -> b⁺ = ceil(σ²/(η²‖ḡ‖²))."""
    st_ = batching.GradStats(
        mean_norm2=jnp.float32(4.0), sigma2=jnp.float32(9.0),
        ip_var=jnp.float32(0.0), orth_var=jnp.float32(0.0),
        b=jnp.float32(8))
    # eq 10 with eta=0.5: ceil(9 / (0.25*4)) = 9
    assert int(batching.norm_test(st_, 0.5)) == 9


def test_inner_product_test_closed_form():
    st_ = batching.GradStats(
        mean_norm2=jnp.float32(2.0), sigma2=jnp.float32(0.0),
        ip_var=jnp.float32(32.0), orth_var=jnp.float32(0.0),
        b=jnp.float32(8))
    # eq 12 with theta=1: ceil(32 / (1*4)) = 8
    assert int(batching.inner_product_test(st_, 1.0)) == 8


def test_augmented_is_max_of_tests():
    st_ = batching.GradStats(
        mean_norm2=jnp.float32(1.0), sigma2=jnp.float32(0.0),
        ip_var=jnp.float32(10.0), orth_var=jnp.float32(100.0),
        b=jnp.float32(8))
    b_ipt = batching.inner_product_test(st_, 0.5)
    b_aug = batching.augmented_test(st_, 0.5, 0.5)
    assert float(b_aug) >= float(b_ipt)
    # orth part: ceil(100 / (0.25 * 1)) = 400 dominates
    assert int(b_aug) == 400


def test_zero_variance_requests_batch_one():
    """Identical per-sample gradients -> sigma2 = 0 -> b+ = 0-ceil -> 1."""
    G = jnp.ones((16, 32))
    st_ = batching.stats_from_matrix(G)
    assert float(st_.sigma2) < 1e-6
    assert int(batching.norm_test(st_, 0.8)) <= 1


def test_monotone_growth_enforced():
    acfg = AdLoCoConfig(eta=0.8)
    st_ = batching.GradStats(jnp.float32(100.0), jnp.float32(1.0),
                             jnp.float32(0.0), jnp.float32(0.0),
                             jnp.float32(4))
    # tiny request, but current_b=32 -> stays 32
    assert batching.requested_batch(st_, acfg, 32) == 32


def test_cap_enforced():
    acfg = AdLoCoConfig(eta=0.01, max_global_batch=128)
    st_ = batching.GradStats(jnp.float32(1e-6), jnp.float32(1e3),
                             jnp.float32(0.0), jnp.float32(0.0),
                             jnp.float32(4))
    assert batching.requested_batch(st_, acfg, 1) == 128


def test_per_sample_stats_match_matrix_path():
    """vmap-of-grad path == hand-built per-sample gradient matrix."""
    def loss_fn(params, batch):
        r = batch["A"] @ params["x"] - batch["y"]
        return 0.5 * jnp.mean(jnp.square(r)), {}

    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((12, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(12), jnp.float32)
    params = {"x": jnp.asarray(rng.standard_normal(8), jnp.float32)}
    st_ = batching.per_sample_stats(loss_fn, params, {"A": A, "y": y})
    # manual per-sample grads: g_i = a_i (a_i.x - y_i)
    G = np.asarray(A) * (np.asarray(A @ params["x"] - y))[:, None]
    n2, sigma2, _, _ = _manual_stats(G)
    assert np.isclose(float(st_.mean_norm2), n2, rtol=1e-4)
    assert np.isclose(float(st_.sigma2), sigma2, rtol=1e-4)


def test_microbatch_estimator_scaling():
    """Var of microbatch means ~ sigma^2 / m: estimator must rescale."""
    rng = np.random.default_rng(2)
    D, m, J = 16, 8, 64
    per_sample = rng.standard_normal((J * m, D)) * 3.0 + 1.0
    micro_means = per_sample.reshape(J, m, D).mean(1)
    st_micro = batching.stats_from_microbatch_grads(
        {"g": jnp.asarray(micro_means, jnp.float32)}, micro_size=m)
    st_full = batching.stats_from_matrix(
        jnp.asarray(per_sample, jnp.float32))
    # rescaled micro sigma2 estimates the per-sample sigma2 (within 25%)
    assert float(st_micro.sigma2) == pytest.approx(
        float(st_full.sigma2), rel=0.25)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(1, 96), st.integers(0, 2 ** 31 - 1))
def test_property_stats_nonnegative_any_matrix(b, dim, seed):
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.standard_normal((b, dim)) * 10, jnp.float32)
    s = batching.stats_from_matrix(G)
    assert float(s.sigma2) >= 0
    assert float(s.ip_var) >= 0
    assert float(s.orth_var) >= -1e-3
    assert float(s.mean_norm2) >= 0


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 2.0), st.integers(0, 2 ** 31 - 1))
def test_property_norm_test_monotone_in_eta(eta, seed):
    """Smaller η (stricter test) must never request a smaller batch."""
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    s = batching.stats_from_matrix(G)
    b1 = float(batching.norm_test(s, eta))
    b2 = float(batching.norm_test(s, eta / 2))
    assert b2 >= b1


# ------------------------------------------------------------------
# distributed composition (the stats all-reduce law) — randomized
# properties; the deterministic fixtures (which must run even without
# hypothesis) live in tests/test_batching_dist.py along with the
# shared helpers
# ------------------------------------------------------------------

from tests.test_batching_dist import (_assert_stats_close,  # noqa: E402
                                      _split_shards)


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 40), st.integers(1, 96),
       st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_property_sharded_stats_compose_exactly(b, dim, cuts, seed):
    """The composition law behind the distributed protocol: GradStats
    all-reduced across k disjoint shards == stats_from_matrix on the
    row-concatenation (to f32 tolerance), for every shard split —
    the five sufficient statistics are additive."""
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.standard_normal((b, dim)) * 3 + 0.7, jnp.float32)
    full = batching.stats_from_matrix(G)
    comp = batching.compose_shards(_split_shards(G, cuts))
    _assert_stats_close(full, comp)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 24), st.integers(1, 64),
       st.integers(0, 2 ** 31 - 1))
def test_property_one_row_per_shard_composes(b, dim, seed):
    """The b=1-per-shard edge (each worker contributes exactly its
    microbatch-mean grad — the distributed microbatch estimator): the
    per-shard statistics are degenerate but the additive moments still
    compose to the full-matrix GradStats."""
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.standard_normal((b, dim)) * 2 - 0.5, jnp.float32)
    full = batching.stats_from_matrix(G)
    comp = batching.compose_shards([G[i:i + 1] for i in range(b)])
    _assert_stats_close(full, comp)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 32), st.integers(2, 48),
       st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=4),
       st.floats(0.2, 1.5), st.integers(0, 2 ** 31 - 1))
def test_property_all_three_tests_agree_on_composed_stats(
        b, dim, cuts, eta, seed):
    """All three batch tests (norm / inner-product / augmented) must
    request the same batch from the composed statistics as from the
    concatenated matrix — the decision, not just the moments, is what
    every rank must agree on."""
    rng = np.random.default_rng(seed)
    G = jnp.asarray(rng.standard_normal((b, dim)) * 3 + 1.0, jnp.float32)
    full = batching.stats_from_matrix(G)
    comp = batching.compose_shards(_split_shards(G, cuts))
    for test in (lambda s: batching.norm_test(s, eta),
                 lambda s: batching.inner_product_test(s, eta),
                 lambda s: batching.augmented_test(s, eta, eta)):
        bf, bc = float(test(full)), float(test(comp))
        # ceil() can disagree by one count right at an integer boundary
        assert abs(bf - bc) <= 1.0 + 1e-2 * max(bf, bc), (bf, bc)


