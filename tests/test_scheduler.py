"""Continuous batching must reproduce per-request greedy decoding
exactly, even when slots hold requests at different positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models, serve
from repro.configs import get_config, reduced
from repro.serve.scheduler import ContinuousBatcher, Request


def _setup(arch):
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "falcon-mamba-7b"])
def test_continuous_matches_sequential_greedy(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, (n,)))
               for n in (3, 5, 7, 4, 6)]
    new = [6, 4, 5, 3, 6]

    # reference: one-by-one generate
    want = []
    for p, n in zip(prompts, new):
        r = serve.generate(params, cfg,
                           jnp.asarray([p], jnp.int32),
                           max_new_tokens=n, cache_len=32)
        want.append(r.tokens[0])

    # continuous batching with fewer slots than requests (forces
    # mid-flight admission at mismatched positions)
    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=32)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=[int(t) for t in p],
                          max_new_tokens=n))
    done = cb.run()
    assert sorted(done) == list(range(5))
    for i in range(5):
        assert done[i].generated == want[i], (arch, i)


def test_slots_refill_midflight():
    cfg, params = _setup("stablelm-1.6b")
    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=24)
    for i in range(4):
        cb.submit(Request(rid=i, tokens=[1 + i, 2, 3],
                          max_new_tokens=2 + i))
    done = cb.run()
    assert len(done) == 4
    # batched decode steps must be fewer than sequential total
    sequential = sum(2 + i for i in range(4))
    assert cb.steps < sequential


def test_decode_step_vector_pos_matches_scalar():
    """decode_step(pos=(B,)) with equal entries == decode_step(scalar)."""
    cfg, params = _setup("qwen3-0.6b")
    prompts = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    _, cache_a = models.prefill(params, prompts, cfg, 16)
    _, cache_b = models.prefill(params, prompts, cfg, 16)
    tok = jnp.asarray([9, 10], jnp.int32)
    la, _ = models.decode_step(params, cache_a, tok, jnp.int32(4), cfg)
    lb, _ = models.decode_step(params, cache_b, tok,
                               jnp.asarray([4, 4], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lb, np.float32),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------- paged
# The paged batcher must be a drop-in: token-for-token identical to the
# dense seed batcher (same lane geometry) and to per-request generate.

@pytest.mark.parametrize("arch", ["qwen3-0.6b", "falcon-mamba-7b",
                                  "hymba-1.5b"])
def test_paged_matches_dense_batcher(arch):
    from repro.serve.scheduler import DenseBatcher
    cfg, params = _setup(arch)
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (n,))))
               for n in (3, 6, 4, 5)]
    new = [5, 3, 6, 4]

    def drive(cb):
        for i, (p, n) in enumerate(zip(prompts, new)):
            cb.submit(Request(rid=i, tokens=p, max_new_tokens=n))
        return cb.run()

    dense = drive(DenseBatcher(params, cfg, n_slots=2, cache_len=32))
    paged = drive(ContinuousBatcher(params, cfg, n_slots=2, cache_len=32,
                                    block_size=8))
    assert sorted(dense) == sorted(paged) == list(range(4))
    for i in range(4):
        assert paged[i].generated == dense[i].generated, (arch, i)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "falcon-mamba-7b"])
def test_chunked_prefill_matches_one_shot(arch):
    """chunk_size < prompt length: prefill spread over several ticks
    must not change a single output token (non-MoE archs: MoE capacity
    dispatch is shape-dependent)."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (n,))))
               for n in (7, 9, 5)]
    want = [serve.generate(params, cfg, jnp.asarray([p], jnp.int32),
                           max_new_tokens=4, cache_len=32).tokens[0]
            for p in prompts]
    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=32,
                           block_size=8, chunk_size=3)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=4))
    done = cb.run()
    for i in range(3):
        assert done[i].generated == want[i], (arch, i)


def test_sampled_outputs_independent_of_scheduler():
    """Counter-based per-request PRNG streams: temperature sampling
    yields identical tokens on the dense and paged batchers even though
    their scheduling differs."""
    from repro.serve.scheduler import DenseBatcher
    cfg, params = _setup("qwen3-0.6b")
    rng = np.random.default_rng(4)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (n,))))
               for n in (4, 6, 3)]

    def drive(cb):
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, tokens=p, max_new_tokens=5,
                              temperature=0.8, top_k=20))
        return cb.run()

    dense = drive(DenseBatcher(params, cfg, n_slots=2, cache_len=32,
                               seed=7))
    paged = drive(ContinuousBatcher(params, cfg, n_slots=3, cache_len=32,
                                    block_size=8, chunk_size=2, seed=7))
    for i in range(3):
        assert paged[i].generated == dense[i].generated, i


def test_admit_rescan_frees_and_refills_same_tick():
    """A request finishing AT prefill (max_new_tokens=1) must not idle
    its lane for a tick: the whole queue drains in one tick here."""
    from repro.serve.scheduler import DenseBatcher
    cfg, params = _setup("stablelm-1.6b")
    for cls, kw in ((DenseBatcher, {}),
                    (ContinuousBatcher, {"block_size": 8})):
        cb = cls(params, cfg, n_slots=1, cache_len=16, **kw)
        for i in range(3):
            cb.submit(Request(rid=i, tokens=[1 + i, 2, 3],
                              max_new_tokens=1))
        done = cb.run()
        assert len(done) == 3
        assert cb.steps == 1, cls.__name__


def test_retired_slot_cache_rows_untouched():
    """Dense batcher: once a slot retires, decode must not write to its
    cache rows (the seed wrote garbage at pos=0 every step)."""
    from repro.serve.scheduler import DenseBatcher
    cfg, params = _setup("qwen3-0.6b")
    cb = DenseBatcher(params, cfg, n_slots=2, cache_len=16)
    cb.submit(Request(rid=0, tokens=[1, 2, 3], max_new_tokens=8))
    cb.submit(Request(rid=1, tokens=[4, 5, 6], max_new_tokens=2))
    while 1 not in cb.finished:
        cb.step()
    lane_b = next(i for i in range(2) if cb.lane_req[i] is None)
    before = np.asarray(cb.cache["k"][:, lane_b])
    cb.step()
    after = np.asarray(cb.cache["k"][:, lane_b])
    np.testing.assert_array_equal(before, after)
    assert 0 in cb.run()


def test_paged_outlives_dense_at_equal_memory():
    """Equal cache memory (64 positions/layer): dense pins concurrency
    at its 2 preallocated slots; the paged pool runs 6 short requests
    at once and matches outputs token-for-token."""
    from repro.serve.scheduler import DenseBatcher
    cfg, params = _setup("qwen3-0.6b")
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (4,))))
               for _ in range(6)]

    def drive(cb):
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, tokens=p, max_new_tokens=4))
        done = cb.run()
        return done, cb.report()

    dense_done, dense_rep = drive(
        DenseBatcher(params, cfg, n_slots=2, cache_len=32))
    paged_done, paged_rep = drive(
        ContinuousBatcher(params, cfg, n_slots=6, cache_len=32,
                          block_size=8, num_blocks=8))
    assert paged_rep.max_concurrency > dense_rep.max_concurrency
    assert paged_rep.max_concurrency == 6 and dense_rep.max_concurrency == 2
    assert paged_rep.ticks < dense_rep.ticks
    for i in range(6):
        assert paged_done[i].generated == dense_done[i].generated, i


def test_preemption_resumes_exactly():
    """A pool too small for both requests' full length forces a
    preempt/requeue/resume cycle; outputs still match per-request
    generate token-for-token."""
    cfg, params = _setup("qwen3-0.6b")
    rng = np.random.default_rng(6)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (6,))))
               for _ in range(2)]
    want = [serve.generate(params, cfg, jnp.asarray([p], jnp.int32),
                           max_new_tokens=8, cache_len=32).tokens[0]
            for p in prompts]
    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=20,
                           block_size=4, num_blocks=5)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=8))
    done = cb.run()
    assert cb.preemptions >= 1
    assert cb.pool.no_leak()
    for i in range(2):
        assert done[i].generated == want[i], i
