"""Continuous batching must reproduce per-request greedy decoding
exactly, even when slots hold requests at different positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models, serve
from repro.configs import get_config, reduced
from repro.serve.scheduler import ContinuousBatcher, Request


def _setup(arch):
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "falcon-mamba-7b"])
def test_continuous_matches_sequential_greedy(arch):
    cfg, params = _setup(arch)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, (n,)))
               for n in (3, 5, 7, 4, 6)]
    new = [6, 4, 5, 3, 6]

    # reference: one-by-one generate
    want = []
    for p, n in zip(prompts, new):
        r = serve.generate(params, cfg,
                           jnp.asarray([p], jnp.int32),
                           max_new_tokens=n, cache_len=32)
        want.append(r.tokens[0])

    # continuous batching with fewer slots than requests (forces
    # mid-flight admission at mismatched positions)
    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=32)
    for i, (p, n) in enumerate(zip(prompts, new)):
        cb.submit(Request(rid=i, tokens=[int(t) for t in p],
                          max_new_tokens=n))
    done = cb.run()
    assert sorted(done) == list(range(5))
    for i in range(5):
        assert done[i].generated == want[i], (arch, i)


def test_slots_refill_midflight():
    cfg, params = _setup("stablelm-1.6b")
    cb = ContinuousBatcher(params, cfg, n_slots=2, cache_len=24)
    for i in range(4):
        cb.submit(Request(rid=i, tokens=[1 + i, 2, 3],
                          max_new_tokens=2 + i))
    done = cb.run()
    assert len(done) == 4
    # batched decode steps must be fewer than sequential total
    sequential = sum(2 + i for i in range(4))
    assert cb.steps < sequential


def test_decode_step_vector_pos_matches_scalar():
    """decode_step(pos=(B,)) with equal entries == decode_step(scalar)."""
    cfg, params = _setup("qwen3-0.6b")
    prompts = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    _, cache_a = models.prefill(params, prompts, cfg, 16)
    _, cache_b = models.prefill(params, prompts, cfg, 16)
    tok = jnp.asarray([9, 10], jnp.int32)
    la, _ = models.decode_step(params, cache_a, tok, jnp.int32(4), cfg)
    lb, _ = models.decode_step(params, cache_b, tok,
                               jnp.asarray([4, 4], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(la, np.float32),
                               np.asarray(lb, np.float32),
                               atol=1e-5, rtol=1e-5)
