"""End-to-end system behaviour: launcher CLI, checkpoint round-trip,
serving loop, data pipeline determinism, roofline/hlo analysis units.

(Algorithm-level behaviour lives in test_adloco_integration.py; this file
covers the framework substrate around it.)
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models, serve
from repro.checkpoint import (latest_step, restore_pytree, save_pytree,
                              save_train_state)
from repro.configs import get_config, reduced
from repro.data import MarkovTokenStream, make_shard_streams
from repro.launch import hlo_analysis
from repro.launch.roofline import (PEAK_FLOPS, load_rows,
                                   model_flops_per_chip)


# ---------------------------------------------------------------- data

def test_stream_deterministic_across_instances():
    a = MarkovTokenStream(256, 32, shard=3, seed=7)
    b = MarkovTokenStream(256, 32, shard=3, seed=7)
    np.testing.assert_array_equal(a.next_batch(4)["tokens"],
                                  b.next_batch(4)["tokens"])


def test_stream_variable_batch_sizes():
    s = MarkovTokenStream(128, 16, shard=0, seed=0)
    for b in (1, 3, 8, 2, 16):
        out = s.next_batch(b)["tokens"]
        assert out.shape == (b, 16)
        assert out.dtype == jnp.int32
        assert int(out.max()) < 128
    assert s.tokens_served == (1 + 3 + 8 + 2 + 16) * 16


def test_shards_distinct_but_same_distribution():
    streams = make_shard_streams(512, 64, 4, seed=1)
    batches = [s.next_batch(8)["tokens"] for s in streams]
    # distinct samples...
    assert not np.array_equal(batches[0], batches[1])
    # ...from the same underlying chain (shared Markov structure)
    assert np.array_equal(streams[0].succ, streams[3].succ)


# ---------------------------------------------------------- checkpoint

def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    p = str(tmp_path / "t.npz")
    save_pytree(p, tree)
    back = restore_pytree(p, tree)
    for l0, l1 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert l0.dtype == l1.dtype
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(l1, np.float32))


def test_full_train_state_checkpoint(tmp_path):
    """Train 2 outer steps on a tiny LM, checkpoint, restore params."""
    from repro.configs.base import AdLoCoConfig
    from repro.core import train_adloco

    cfg = reduced(get_config("microllama-300m"))
    acfg = AdLoCoConfig(num_outer_steps=2, num_inner_steps=2,
                        num_init_trainers=2, nodes_per_gpu=1,
                        initial_batch_size=2, max_batch=4,
                        stats_probe_size=4, lr_inner=1e-3,
                        inner_optimizer="sgd")
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    inits = [models.init_params(cfg, k) for k in keys]
    streams = make_shard_streams(cfg.vocab_size, 16, 2, seed=0)
    loss = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731
    pool, _ = train_adloco(loss, inits, streams, acfg)

    ckpt = str(tmp_path / "ckpt")
    save_train_state(ckpt, 2, pool)
    assert latest_step(ckpt) == 2
    d = os.path.join(ckpt, "step_00000002")
    restored = restore_pytree(os.path.join(d, "global_params.npz"),
                              pool.global_params)
    for l0, l1 in zip(jax.tree.leaves(pool.global_params),
                      jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(l1, np.float32))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    assert meta["num_trainers"] == pool.k


# -------------------------------------------------------------- serve

def test_generate_greedy_deterministic():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)),
        jnp.int32)
    r1 = serve.generate(params, cfg, prompts, max_new_tokens=6)
    r2 = serve.generate(params, cfg, prompts, max_new_tokens=6)
    assert r1.tokens == r2.tokens
    assert len(r1.tokens) == 2 and len(r1.tokens[0]) == 6
    assert all(0 <= t < cfg.vocab_size for row in r1.tokens for t in row)


def test_generate_matches_argmax_of_prefill():
    """First generated token == argmax of the prefill's last logits."""
    cfg = reduced(get_config("stablelm-1.6b"))
    params = models.init_params(cfg, jax.random.PRNGKey(1))
    prompts = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    logits, _ = models.prefill(params, prompts, cfg, 16)
    expect = int(jnp.argmax(logits[:, -1], axis=-1)[0])
    r = serve.generate(params, cfg, prompts, max_new_tokens=1,
                       cache_len=16)
    assert r.tokens[0][0] == expect


def test_generate_ssm_decode():
    """SSM path has O(1) state decode — generate must work without a
    KV cache."""
    cfg = reduced(get_config("falcon-mamba-7b"))
    params = models.init_params(cfg, jax.random.PRNGKey(2))
    prompts = jnp.asarray([[5, 6, 7]], jnp.int32)
    r = serve.generate(params, cfg, prompts, max_new_tokens=4, cache_len=8)
    assert len(r.tokens[0]) == 4


# ----------------------------------------------------------- launcher

def test_train_cli_end_to_end(tmp_path):
    from repro.launch import train as train_cli
    hist_out = str(tmp_path / "hist.json")
    rc = train_cli.main([
        "--arch", "microllama-300m", "--reduced",
        "--outer-steps", "2", "--inner-steps", "2",
        "--trainers", "2", "--workers", "1", "--seq-len", "16",
        "--max-batch", "4", "--initial-batch", "2",
        "--history-out", hist_out,
    ])
    assert rc == 0
    with open(hist_out) as f:
        hist = json.load(f)
    assert len(hist["loss"]) == 2
    assert hist["comm_events"][-1] >= 2  # one outer sync per trainer/step
    assert all(np.isfinite(hist["loss"]))


# ------------------------------------------------- hlo/roofline units

_TOY_HLO = """\
HloModule toy

%body (p: (f32[8,8], s32[])) -> (f32[8,8], s32[]) {
  %p = (f32[8,8], s32[]) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=0
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  ROOT %t = (f32[8,8], s32[]) tuple(%ar, %ni)
}

%cond (p: (f32[8,8], s32[])) -> pred[] {
  %p = (f32[8,8], s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=1
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> (f32[8,8], s32[]) {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (f32[8,8], s32[]) tuple(%a, %z)
  ROOT %w = (f32[8,8], s32[]) while(%init), condition=%cond, body=%body
}
"""


def test_hlo_trip_count_correction():
    """The while body (one 8x8x8 dot = 1024 flops, one 256-byte
    all-reduce) must be counted 10x, unlike XLA's cost_analysis."""
    res = hlo_analysis.analyze(_TOY_HLO)
    assert res["flops"] == pytest.approx(10 * 2 * 8 * 8 * 8)
    assert res["collective_bytes"] == pytest.approx(10 * 8 * 8 * 4)
    # ring model: all-reduce wire factor 2
    assert res["collective_wire_bytes"] == pytest.approx(2 * 10 * 8 * 8 * 4)


def test_roofline_rows_load_and_terms():
    rows = load_rows()
    if not rows:
        pytest.skip("no dry-run artifacts present")
    by_key = {(r.arch, r.shape, r.mesh): r for r in rows
              if r.accum == 1}
    # every row internally consistent
    for r in rows:
        assert r.bound_s == pytest.approx(
            max(r.compute_s, r.memory_s, r.collective_s))
        assert r.dominant in ("compute", "memory", "collective")
        assert r.compute_s == pytest.approx(r.hlo_flops / PEAK_FLOPS)
    # the full assigned baseline grid must be present (single pod)
    from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, LONG_CONTEXT_ARCHS
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS \
                    and get_config(arch).arch_type != "ssm":
                continue
            assert (arch, shape, "pod16x16") in by_key, (arch, shape)


def test_model_flops_train_formula():
    cfg = get_config("qwen3-0.6b")
    n = cfg.param_count(active_only=True)
    got = model_flops_per_chip("qwen3-0.6b", "train_4k", 256)
    assert got == pytest.approx(6.0 * n * 256 * 4096 / 256)


def test_model_flops_moe_uses_active_params():
    dense_n = get_config("deepseek-moe-16b").param_count()
    active_n = get_config("deepseek-moe-16b").param_count(active_only=True)
    assert active_n < 0.4 * dense_n  # top-6 of 64 routed
    got = model_flops_per_chip("deepseek-moe-16b", "prefill_32k", 256)
    assert got == pytest.approx(2.0 * active_n * 32 * 32768 / 256)


def test_restore_train_state_roundtrip(tmp_path):
    """Full pool save -> restore into freshly-initialised templates."""
    from repro.configs.base import AdLoCoConfig
    from repro.core import train_adloco
    from repro.checkpoint import restore_train_state

    cfg = reduced(get_config("microllama-300m"))
    acfg = AdLoCoConfig(num_outer_steps=2, num_inner_steps=2,
                        num_init_trainers=2, nodes_per_gpu=1,
                        initial_batch_size=2, max_batch=4,
                        stats_probe_size=4, lr_inner=1e-3,
                        inner_optimizer="sgd", enable_merge=False)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    inits = [models.init_params(cfg, k) for k in keys]
    streams = make_shard_streams(cfg.vocab_size, 16, 2, seed=0)
    loss = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731
    pool, _ = train_adloco(loss, inits, streams, acfg)
    save_train_state(str(tmp_path), 2, pool)

    # fresh templates with the same structure
    inits2 = [models.init_params(cfg, k) for k in keys]
    pool2, _ = train_adloco(loss, inits2, streams, acfg,
                            num_outer_steps=1)
    pool2, meta = restore_train_state(str(tmp_path), 2, pool2)
    assert meta["step"] == 2
    for tr_a, tr_b in zip(pool.trainers, pool2.trainers):
        assert tr_a.requested_batch == tr_b.requested_batch
        for l0, l1 in zip(jax.tree.leaves(tr_a.params),
                          jax.tree.leaves(tr_b.params)):
            np.testing.assert_allclose(np.asarray(l0, np.float32),
                                       np.asarray(l1, np.float32))
