"""Unit + integration tests for the batch-growth autoscaling layer.

Covers the redesigned run configuration (``ClusterSpec`` vs the legacy
keyword aliases — same behavior, mixing rejected), the named
:class:`~repro.cluster.scenarios.Scenario` record, the
:class:`~repro.cluster.autoscale.BandAutoscale` policy in isolation,
the exhausted-spares ``join_skipped`` regression, and an end-to-end
autoscaled run (pool co-scales with the adaptive batch, joiners inherit
the batch trajectory, the scenario name reaches the extended summary).
Golden digests for the autoscaled scenarios live in
``tests/test_scenarios.py``; this module pins the API semantics.
"""
import dataclasses

import pytest

from repro.configs.base import AdLoCoConfig
from repro.cluster import (BandAutoscale, ClusterEvent, ClusterSpec,
                           Topology, interleave_pods, make_pod_profiles,
                           run_cluster)
from repro.cluster.autoscale import ElasticPolicy
from repro.cluster.scenarios import Scenario, build_scenario

from tests.test_adloco_integration import QuadStream, _quad_setup, quad_loss

TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)

ACFG = AdLoCoConfig(num_outer_steps=6, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=2, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32,
                    enable_merge=False, adaptive=False)


def _cluster(k=2, M=2, pods=(4, 4), spares=0):
    profiles = interleave_pods(make_pod_profiles(list(pods), ratio=2.0,
                                                 **TOY))
    topo = Topology.from_profiles(
        make_pod_profiles(list(pods), ratio=2.0, **TOY),
        inter_bw=1e5, inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=k, M=M)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(spares)]
    return profiles, topo, inits, streams


# ------------------------------------------------------- BandAutoscale

def test_band_autoscale_edges_and_bounds():
    pol = BandAutoscale(lo=2.0, hi=8.0, cooldown_rounds=0)
    dec = lambda **kw: pol.decide(rounds_since_change=99, **kw)
    # inside the band (including both edges): hold
    assert dec(requested_batch=16, pool_size=2, spare_capacity=5) == 0
    assert dec(requested_batch=16, pool_size=8, spare_capacity=5) == 0
    # above hi: join — but only with spare capacity
    assert dec(requested_batch=17, pool_size=2, spare_capacity=5) == 1
    assert dec(requested_batch=17, pool_size=2, spare_capacity=0) == 0
    # below lo: leave — but never below min_trainers
    assert dec(requested_batch=3, pool_size=2, spare_capacity=5) == -1
    assert dec(requested_batch=3, pool_size=1, spare_capacity=5) == 0
    # max_trainers caps joins
    capped = BandAutoscale(lo=2.0, hi=8.0, max_trainers=2)
    assert capped.decide(requested_batch=100, pool_size=2,
                         spare_capacity=5, rounds_since_change=9) == 0


def test_band_autoscale_cooldown_suppresses_actions():
    pol = BandAutoscale(lo=2.0, hi=8.0, cooldown_rounds=3)
    kw = dict(requested_batch=100, pool_size=2, spare_capacity=5)
    assert pol.decide(rounds_since_change=0, **kw) == 0
    assert pol.decide(rounds_since_change=2, **kw) == 0
    assert pol.decide(rounds_since_change=3, **kw) == 1


def test_band_autoscale_validates_knobs():
    with pytest.raises(ValueError, match="0 < lo < hi"):
        BandAutoscale(lo=8.0, hi=2.0)
    with pytest.raises(ValueError, match="0 < lo < hi"):
        BandAutoscale(lo=0.0, hi=2.0)
    with pytest.raises(ValueError, match="min_trainers"):
        BandAutoscale(min_trainers=0)
    with pytest.raises(ValueError, match="max_trainers"):
        BandAutoscale(min_trainers=3, max_trainers=2)
    assert "BandAutoscale" in BandAutoscale().describe()


def test_elastic_policy_protocol_is_abstract():
    with pytest.raises(NotImplementedError):
        ElasticPolicy().decide(requested_batch=1, pool_size=1,
                               spare_capacity=0, rounds_since_change=0)


# ----------------------------------------------------- Scenario record

def test_scenario_record_behaves_as_event_sequence():
    sc = build_scenario("spot_churn")
    assert isinstance(sc, Scenario)
    assert sc.name == "spot_churn" and sc.knobs == {}
    assert len(sc) == len(sc.events) > 0
    assert sc[0] is sc.events[0]
    assert list(sc) == list(sc.events)
    extra = [ClusterEvent(time=9.9, kind="join")]
    # + concatenates to a raw event list in either order
    assert sc + extra == list(sc.events) + extra
    assert extra + sc == extra + list(sc.events)
    # knobs travel with the record
    storm = build_scenario("preemption_storm_growth", leaves=3)
    assert storm.knobs == {"leaves": 3}


def test_build_scenario_unknown_name_lists_registry():
    with pytest.raises(ValueError, match="unknown scenario"):
        build_scenario("not_a_scenario")


# ------------------------------------------------- ClusterSpec redesign

def test_spec_and_legacy_kwargs_are_equivalent():
    """The whole point of the alias shim: the same run configured
    through spec= and through the legacy keywords must be identical —
    summary, applied events, and per-round history."""
    def go(via_spec):
        profiles, topo, inits, streams = _cluster(spares=4)
        kw = dict(policy="elastic", profiles=profiles, network=topo,
                  scenario="spot_churn", fixed_batch=4)
        if via_spec:
            return run_cluster(quad_loss, inits, streams, ACFG,
                               spec=ClusterSpec(**kw))
        return run_cluster(quad_loss, inits, streams, ACFG, **kw)

    (_, hist_a, rep_a), (_, hist_b, rep_b) = go(True), go(False)
    assert rep_a.summary(extended=True) == rep_b.summary(extended=True)
    assert rep_a.applied_events == rep_b.applied_events
    assert hist_a.requested_batches == hist_b.requested_batches
    assert hist_a.sim_time == hist_b.sim_time


def test_spec_cannot_be_mixed_with_legacy_kwargs():
    profiles, topo, inits, streams = _cluster()
    spec = ClusterSpec(policy="elastic", profiles=profiles, network=topo,
                       fixed_batch=4)
    with pytest.raises(ValueError, match="not both"):
        run_cluster(quad_loss, inits, streams, ACFG, spec=spec,
                    fixed_batch=4)


def test_spec_is_frozen():
    spec = ClusterSpec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.policy = "elastic"


def test_autoscale_requires_elastic_policy():
    profiles, topo, inits, streams = _cluster()
    for policy in ("sync", "async"):
        with pytest.raises(ValueError, match="elastic"):
            run_cluster(quad_loss, inits, streams, ACFG,
                        spec=ClusterSpec(policy=policy, profiles=profiles,
                                         network=topo, fixed_batch=4,
                                         autoscale=BandAutoscale()))


# ------------------------------------------- join_skipped (regression)

def test_exhausted_spares_record_join_skipped():
    """A scripted join with no spare streams/nodes used to be dropped
    silently; it must now land in applied_events with the shortfall."""
    profiles, topo, inits, streams = _cluster(spares=0)  # exactly k*M
    _, _, rep = run_cluster(
        quad_loss, inits, streams, ACFG,
        spec=ClusterSpec(policy="elastic", profiles=profiles, network=topo,
                         fixed_batch=4,
                         scenario=[ClusterEvent(time=0.01, kind="join")]))
    skips = [e for e in rep.applied_events if e["kind"] == "join_skipped"]
    assert len(skips) == 1
    ev = skips[0]
    assert ev["needed"] == ACFG.nodes_per_gpu
    # streams are the binding shortfall here (profiles have spares)
    assert ev["free_streams"] == 0 and ev["free_nodes"] >= 0
    assert not any(e["kind"] == "join" for e in rep.applied_events)


# --------------------------------------------------- end-to-end runs

def _autoscaled_run(k_correct=3, rounds=12):
    profiles, topo, inits, streams = _cluster(k=2, M=2, pods=(6, 6),
                                              spares=8)
    acfg = dataclasses.replace(ACFG, adaptive=True,
                               stats_estimator="microbatch",
                               num_outer_steps=rounds,
                               max_global_batch=256, k_correct=k_correct)
    spec = ClusterSpec(policy="elastic", profiles=profiles, network=topo,
                       scenario="autoscale_ramp",
                       autoscale=BandAutoscale(lo=2.0, hi=8.0,
                                               cooldown_rounds=2))
    return run_cluster(quad_loss, inits, streams, acfg, spec=spec)


def test_autoscaled_run_coscales_pool_with_batch():
    _, hist, rep = _autoscaled_run()
    # the ramp pushed gradients-per-worker over the band: the policy
    # scripted at least one join and the pool actually grew
    assert rep.num_autoscale_events > 0
    acts = [e for e in rep.applied_events if e["kind"] == "autoscale"]
    assert acts and all(e["action"] != 0 for e in acts)
    assert {"action", "pool", "requested_batch",
            "gradients_per_worker"} <= set(acts[0])
    assert any(e["kind"] == "join" for e in rep.applied_events)
    # co-scaling in both directions: the tiny initial batch puts
    # gradients-per-worker below the band (early leave), then the ramp
    # grows the pool past its starting size
    assert min(hist.pool_size) < 2 and max(hist.pool_size) > 2
    # joiners inherit the source's batch trajectory instead of restarting
    # from initial_batch_size: the first history row recorded after the
    # pool grew has no trainer back at the initial batch
    grew = next(i for i in range(1, len(hist.pool_size))
                if hist.pool_size[i] > hist.pool_size[i - 1])
    assert min(hist.requested_batches[grew]) > ACFG.initial_batch_size
    # the compiled scenario's name reaches the extended summary
    s = rep.summary(extended=True)
    assert s["scenario"] == "autoscale_ramp"
    assert s["num_autoscale_events"] == rep.num_autoscale_events


def test_autoscaled_run_predicts_between_corrections():
    _, _, rep = _autoscaled_run(k_correct=3)
    _, _, rep_exact = _autoscaled_run(k_correct=1)
    # predicted rounds pay no stats reduction, corrections still do
    assert rep.num_predicted_rounds > 0
    assert rep_exact.num_predicted_rounds == 0
    assert 0 < rep.num_stats_syncs < rep_exact.num_stats_syncs
