"""Pallas kernel validation vs pure-jnp oracles (interpret mode on CPU):
shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# property tests ride along whenever hypothesis is installed (CI pins
# it); without it the whole module is skipped rather than erroring at
# collection
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gradstats.ops import gradstats_reduce
from repro.kernels.gradstats.ref import gradstats_reduce_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------
# flash attention
# ------------------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, Hk, hd, window, causal, dtype)
    (2, 256, 4, 2, 64, None, True, jnp.float32),
    (1, 128, 8, 8, 32, None, True, jnp.float32),
    (2, 256, 4, 1, 64, 100, True, jnp.float32),
    (1, 384, 6, 3, 128, 64, True, jnp.float32),
    (1, 256, 2, 2, 64, None, False, jnp.float32),     # bidirectional
    (2, 192, 4, 2, 64, None, True, jnp.bfloat16),     # bf16 + pad (192)
    (1, 96, 4, 4, 80, None, True, jnp.float32),       # odd hd, pad
]


@pytest.mark.parametrize("B,S,H,Hk,hd,window,causal,dtype", FLASH_CASES)
def test_flash_attention_allclose(B, S, H, Hk, hd, window, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


def test_flash_attention_dynamic_window_traced():
    """Window passed as a traced scalar (gemma's local/global scan)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))

    @jax.jit
    def run(w):
        return flash_attention(q, k, v, causal=True, window=w)

    for w in (16, 64, 1 << 20):
        out = run(jnp.int32(w))
        ref = flash_attention_ref(q, k, v, causal=True, window=int(w))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 2), st.sampled_from([64, 128, 160]),
       st.sampled_from([(2, 1), (4, 2), (4, 4)]),
       st.sampled_from([32, 64]), st.integers(0, 2 ** 31 - 1))
def test_property_flash_matches_ref(B, S, heads, hd, seed):
    H, Hk = heads
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hk, hd))
    v = jax.random.normal(ks[2], (B, S, Hk, hd))
    out = flash_attention(q, k, v, causal=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ------------------------------------------------------------------
# mamba selective scan
# ------------------------------------------------------------------

MAMBA_CASES = [
    (2, 256, 128, 16, jnp.float32),
    (1, 200, 96, 8, jnp.float32),       # padding both axes
    (2, 64, 256, 16, jnp.float32),
    (1, 128, 128, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,di,n,dtype", MAMBA_CASES)
def test_mamba_scan_allclose(B, S, di, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    u = jax.random.normal(ks[0], (B, S, di), dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.1
          ).astype(dtype)
    A_log = jnp.log(jnp.abs(jax.random.normal(ks[2], (di, n))) + 0.5)
    Bm = jax.random.normal(ks[3], (B, S, n), dtype)
    Cm = jax.random.normal(ks[4], (B, S, n), dtype)
    y, h = mamba_scan(u, dt, A_log, Bm, Cm)
    yr, hr = mamba_scan_ref(u, dt, A_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), **_tol(dtype))


def test_mamba_scan_matches_naive_recurrence():
    """Kernel vs an explicit python-loop recurrence (ground truth)."""
    B, S, di, n = 1, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    u = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.2
    A_log = jnp.log(jnp.abs(jax.random.normal(ks[2], (di, n))) + 0.5)
    Bm = jax.random.normal(ks[3], (B, S, n))
    Cm = jax.random.normal(ks[4], (B, S, n))
    A = -np.exp(np.asarray(A_log))
    h = np.zeros((B, di, n))
    ys = []
    for t in range(S):
        a = np.exp(np.asarray(dt)[:, t, :, None] * A[None])
        h = a * h + (np.asarray(dt)[:, t] * np.asarray(u)[:, t])[..., None] \
            * np.asarray(Bm)[:, t, None, :]
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(Cm)[:, t]))
    y_ref = np.stack(ys, 1)
    y, h_last = mamba_scan(u, dt, A_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.sampled_from([32, 96, 128]),
       st.sampled_from([64, 160]), st.sampled_from([8, 16]),
       st.integers(0, 2 ** 31 - 1))
def test_property_mamba_matches_ref(B, S, di, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    u = jax.random.normal(ks[0], (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di))) * 0.1
    A_log = jnp.log(jnp.abs(jax.random.normal(ks[2], (di, n))) + 0.5)
    Bm = jax.random.normal(ks[3], (B, S, n))
    Cm = jax.random.normal(ks[4], (B, S, n))
    y, h = mamba_scan(u, dt, A_log, Bm, Cm)
    yr, hr = mamba_scan_ref(u, dt, A_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------
# gradstats
# ------------------------------------------------------------------

@pytest.mark.parametrize("B,D,dtype", [
    (16, 1024, jnp.float32), (7, 300, jnp.float32), (64, 4096, jnp.float32),
    (3, 130, jnp.float32), (32, 2048, jnp.bfloat16),
])
def test_gradstats_allclose(B, D, dtype):
    G = jax.random.normal(jax.random.PRNGKey(2), (B, D), dtype)
    s, d, n2, b = gradstats_reduce(G)
    sr, dr, n2r, br = gradstats_reduce_ref(G)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), **tol)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), **tol)
    np.testing.assert_allclose(float(n2), float(n2r), **tol)
    assert float(b) == B


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 48), st.integers(16, 700),
       st.integers(0, 2 ** 31 - 1))
def test_property_gradstats_matches_ref(B, D, seed):
    G = jax.random.normal(jax.random.PRNGKey(seed), (B, D)) * 3
    s, d, n2, b = gradstats_reduce(G)
    sr, dr, n2r, _ = gradstats_reduce_ref(G)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(n2), float(n2r), rtol=1e-4, atol=1e-5)


#: the shapes the distributed estimator actually feeds the kernel:
#: B = worker/probe counts (rarely a power of two, B=1 when a single
#: microbatch-mean row is the local shard), D = flattened param dims
#: (never a multiple of the 512-lane tile for toy models)
GRADSTATS_EDGE_CASES = [
    (1, 16, jnp.float32),     # single-row shard (microbatch estimator)
    (1, 513, jnp.float32),
    (2, 16, jnp.float32),     # the 2-worker fixture, tiny D
    (5, 193, jnp.float32),
    (9, 515, jnp.float32),
    (13, 1027, jnp.float32),
    (3, 130, jnp.bfloat16),   # bf16 on non-pow2 both axes
    (5, 193, jnp.bfloat16),
    (17, 700, jnp.bfloat16),
    (31, 1000, jnp.bfloat16),
]


@pytest.mark.parametrize("B,D,dtype", GRADSTATS_EDGE_CASES)
def test_gradstats_kernel_nonpow2_and_dtypes(B, D, dtype):
    """Kernel == oracle on exactly the ragged shapes and dtypes the
    distributed estimator produces (zero-padding must stay exact)."""
    G = jax.random.normal(jax.random.PRNGKey(B * 1000 + D), (B, D),
                          dtype) * 2 + jnp.asarray(0.3, dtype)
    s, d, n2, b = gradstats_reduce(G)
    sr, dr, n2r, br = gradstats_reduce_ref(G)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), **tol)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), **tol)
    np.testing.assert_allclose(float(n2), float(n2r), **tol)
    assert float(b) == float(br) == B
    assert s.shape == d.shape == (B,)
    # outputs are f32 accumulators regardless of the input dtype
    assert s.dtype == d.dtype == jnp.float32


@pytest.mark.parametrize("B,D,dtype", GRADSTATS_EDGE_CASES)
def test_stats_from_matrix_kernel_path_matches_ref_path(B, D, dtype):
    """use_kernel=True must be a drop-in for the derived GradStats —
    the contract that lets TrainerRound route the adaptive estimators
    through the fused kernel (acfg.stats_use_kernel)."""
    from repro.core import batching

    G = jax.random.normal(jax.random.PRNGKey(B + 7 * D), (B, D),
                          dtype) * 3
    a = batching.stats_from_matrix(G, use_kernel=False)
    k = batching.stats_from_matrix(G, use_kernel=True)
    scale = max(abs(float(v)) for v in a) + 1e-6
    rel = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    for name, x, y in zip(batching.GradStats._fields, a, k):
        assert abs(float(x) - float(y)) <= \
            rel * max(abs(float(x)), abs(float(y))) + rel * scale, \
            (name, float(x), float(y))
