"""Tests for the event-driven virtual-cluster runtime (repro.cluster):
policy equivalences, straggler timing, elastic pool invariants, and the
network/node cost models."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco
from repro.core.comms import (CommDomain, hierarchical_allreduce_time,
                              param_bytes, ring_allreduce_time)
from repro.core.mit import (TrainerPoolState, TrainerState, check_merge,
                            consolidate, do_merge)
from repro.cluster import (ClusterEvent, FabricDomain, FabricSchedule,
                           NetworkModel, NodeProfile, Topology,
                           interleave_pods, make_heterogeneous_profiles,
                           make_pod_profiles, make_rack_profiles,
                           run_cluster)

from tests.test_adloco_integration import QuadStream, _quad_setup, quad_loss


BASE = AdLoCoConfig(num_outer_steps=8, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=3, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32)

# toy-scale hardware so compute and comm times are comparable on the
# 16-dim quadratic (v5e constants make both vanish)
TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)


def _eval_fn(prob):
    return lambda p: 0.5 * float(
        jnp.sum(jnp.square(p["x"] - prob.x_star))) + 0.5 * prob.noise ** 2


def _profiles(n, ratio=1.0, jitter=0.0, seed=0):
    return make_heterogeneous_profiles(n, ratio=ratio, jitter=jitter,
                                       seed=seed, **TOY)


# --------------------------------------------------------------- cost models

def test_ring_allreduce_time_model():
    # p=1: nothing to exchange
    assert ring_allreduce_time(1e6, 1, 1e9) == 0.0
    # bandwidth term: 2(p-1)/p * payload / bw
    t4 = ring_allreduce_time(1e6, 4, 1e9, latency=0.0)
    assert t4 == pytest.approx(2 * 3 / 4 * 1e6 / 1e9)
    # latency term: 2(p-1) hops
    t_lat = ring_allreduce_time(8, 4, 1e12, latency=1e-3)
    assert t_lat == pytest.approx(6e-3, rel=1e-3)
    # more participants at fixed payload -> more wire time per node
    assert ring_allreduce_time(1e6, 8, 1e9) > ring_allreduce_time(1e6, 2, 1e9)


def test_node_profile_slowdown_and_heterogeneity():
    prof = NodeProfile.from_roofline(speed=1.0, **TOY)
    base = prof.compute_time(1e6, 0.0, now=0.0)
    assert base == pytest.approx(1.0)
    prof.add_slowdown(start=10.0, duration=5.0, factor=3.0)
    assert prof.compute_time(1e6, 0.0, now=12.0) == pytest.approx(3.0)
    assert prof.compute_time(1e6, 0.0, now=20.0) == pytest.approx(1.0)

    profs = _profiles(4, ratio=4.0)
    speeds = [p.flops for p in profs]
    assert speeds[0] / speeds[-1] == pytest.approx(4.0)
    assert all(a >= b for a, b in zip(speeds, speeds[1:]))


def test_network_model_bottlenecked_by_slowest_link():
    fast = NodeProfile.from_roofline(name="f", **TOY)
    slow = NodeProfile.from_roofline(name="s", speed=0.25, **TOY)
    net = NetworkModel()
    t_ff = net.allreduce_time(1e4, [fast, fast])
    t_fs = net.allreduce_time(1e4, [fast, slow])
    assert t_fs > t_ff


def test_point_to_point_rejects_nonpositive_bandwidth():
    """A zero/negative-bandwidth misconfiguration must fail loudly, not
    silently price the transfer at the old 1 byte/s floor."""
    good = NodeProfile.from_roofline(name="g", **TOY)
    dead = NodeProfile.from_roofline(name="d", **TOY)
    dead.link_bw = 0.0
    with pytest.raises(ValueError, match="bandwidth"):
        NetworkModel().point_to_point_time(1e3, good, dead)
    with pytest.raises(ValueError, match="bandwidth"):
        NetworkModel().allreduce_time(1e3, [good, dead])
    topo = Topology(pods=[["g", "d"]], inter_bw=1e5)
    with pytest.raises(ValueError, match="bandwidth"):
        topo.point_to_point_time(1e3, good, dead)
    with pytest.raises(ValueError, match="intra_bw"):
        topo.allreduce_time(1e3, [good, dead])
    # a healthy pair still prices finitely
    assert NetworkModel().point_to_point_time(1e3, good, good) > 0.0


def test_network_model_rejects_conflicting_baseline():
    """Passing a fabric schedule and the legacy bw_scale/extra_latency
    constants together would silently drop the constants."""
    with pytest.raises(ValueError, match="FabricSchedule"):
        NetworkModel(bw_scale=0.5, fabric=FabricSchedule())
    # either spelling alone works and prices identically
    a = NetworkModel(bw_scale=0.5)
    b = NetworkModel(fabric=FabricSchedule(bw_scale=0.5))
    n0 = NodeProfile.from_roofline(name="n0", **TOY)
    n1 = NodeProfile.from_roofline(name="n1", **TOY)
    assert a.allreduce_time(1e3, [n0, n1]) == b.allreduce_time(1e3, [n0, n1])


def test_fabric_schedule_windows_compose():
    sched = FabricSchedule(bw_scale=1.0, extra_latency=0.0)
    sched.add_window(1.0, 2.0, bw_scale=0.5, extra_latency=1e-3)
    sched.add_window(2.0, 2.0, bw_scale=0.5, extra_latency=1e-3)
    assert sched.at(0.5) == (1.0, 0.0)
    assert sched.at(1.5) == (0.5, 1e-3)              # first window only
    assert sched.at(2.5) == (0.25, 2e-3)             # overlap: composed
    assert sched.at(3.5) == (0.5, 1e-3)              # second window only
    assert sched.at(4.0) == (1.0, 0.0)               # half-open intervals
    sched.add_window(9.0, None, bw_scale=0.1)        # permanent
    assert sched.at(1e9) == (0.1, 0.0)
    with pytest.raises(ValueError, match="bw_scale"):
        sched.add_window(0.0, 1.0, bw_scale=0.0)


def test_topology_routes_through_pods():
    """Cross-pod collectives pay the bottleneck; intra-pod ones price
    exactly like the flat ring (the hierarchical model collapses)."""
    profiles = make_pod_profiles([2, 2], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=5e4,
                                  inter_latency=4e-3)
    a0, a1, b0, b1 = profiles
    intra = topo.allreduce_time(1e3, [a0, a1])
    assert intra == NetworkModel().allreduce_time(1e3, [a0, a1])
    cross = topo.allreduce_time(1e3, [a0, b0])
    assert cross == hierarchical_allreduce_time(
        1e3, [1, 1], a0.link_bw, 5e4, intra_latency=a0.link_latency,
        inter_latency=4e-3)
    assert cross > intra                 # the bottleneck link is slower
    # congestion on the inter fabric leaves intra-pod pricing untouched
    topo.add_fabric_window(0.0, 1.0, bw_scale=0.1, scope="inter")
    assert topo.allreduce_time(1e3, [a0, a1], now=0.5) == intra
    assert topo.allreduce_time(1e3, [a0, b0], now=0.5) > cross
    with pytest.raises(ValueError, match="not in the topology"):
        topo.allreduce_time(1e3, [a0, NodeProfile.from_roofline(
            name="stranger", **TOY)])
    with pytest.raises(ValueError, match="scope"):
        topo.add_fabric_window(0.0, 1.0, scope="wat")


def test_topology_prices_each_pod_ring_at_its_own_bandwidth():
    """Mixed-generation pods: the fast pod's reduce-scatter must not be
    billed at the slow pod's link speed — the critical path is the max
    of the per-pod times, each at that pod's own bandwidth."""
    profiles = make_pod_profiles([3, 1], ratio=2.0, **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e9,
                                  inter_latency=0.0)
    a0, a1, a2, b0 = profiles
    assert b0.link_bw == pytest.approx(a0.link_bw / 2)
    t = topo.allreduce_time(1e3, profiles)
    lat = max(p.link_latency for p in profiles)
    # slow pod has one node (its ring is free): the critical scatter is
    # the fast pod's, at the fast pod's own bandwidth
    scatter = 2 * lat + (2 / 3 * 1e3) / a0.link_bw
    cross = ring_allreduce_time(1e3, 2, 1e9, 0.0)
    assert t == pytest.approx(2 * scatter + cross)
    # the old global-min pricing billed that ring at the slow pod's bw
    old = 2 * (2 * lat + (2 / 3 * 1e3) / b0.link_bw) + cross
    assert t < old
    # latency is per-pod too: a high-latency pod whose ring has no hops
    # (single node) must not tax the fast pod's hops
    b0.link_latency = 0.1
    assert topo.allreduce_time(1e3, profiles) == pytest.approx(t)


# ------------------------------------------- n-level differential tests

def test_hierarchical_depth1_is_exactly_the_ring():
    """A single leaf domain must price bit-for-bit as the flat ring —
    the depth-1 base case of the level recursion."""
    for p in (1, 2, 3, 7, 64):
        for payload in (1.0, 64.0, 3.3e7):
            leaf = CommDomain(bw=3.7e5, latency=1.3e-3, size=p)
            assert hierarchical_allreduce_time(payload, leaf) == \
                ring_allreduce_time(payload, p, 3.7e5, 1.3e-3)


def test_hierarchical_depth2_matches_pod_implementation():
    """The depth-2 tree spelling must reproduce the PR 2 pod
    implementation bit-for-bit: same values as the legacy pod-sizes
    signature *and* as the original closed form (per-pod reduce-scatter
    critical path, cross-pod shard ring, per-pod all-gather) — no
    silent re-pricing of existing scenarios."""
    fixtures = [
        # (pod_sizes, intra_bw(s), inter_bw, intra_lat(s), inter_lat)
        ([5, 5], 2e5, 1e5, 2e-3, 4e-3),          # test_scenarios fixture
        ([3, 3], 2e5, 1e5, 2e-3, 4e-3),          # cluster_bench fixture
        ([2, 2], 2e5, 5e4, 2e-3, 4e-3),
        ([3, 1], [2e5, 1e5], 1e9, [2e-3, 2e-3], 0.0),   # mixed-gen pods
        ([1, 1], 2e5, 1e5, 2e-3, 4e-3),
        ([4, 2, 7], [3e5, 1e5, 2e5], 8e4, [1e-3, 2e-3, 0.0], 5e-3),
    ]
    for sizes, intra, inter, ilat, xlat in fixtures:
        for payload in (64.0, 1e3, 7.7e8):
            legacy = hierarchical_allreduce_time(
                payload, sizes, intra, inter, intra_latency=ilat,
                inter_latency=xlat)
            bws = intra if isinstance(intra, list) else [intra] * len(sizes)
            lats = ilat if isinstance(ilat, list) else [ilat] * len(sizes)
            tree = CommDomain(bw=inter, latency=xlat, children=[
                CommDomain(bw=b, latency=l, size=s)
                for s, b, l in zip(sizes, bws, lats)])
            assert hierarchical_allreduce_time(payload, tree) == legacy
            # the PR 2 closed form, inlined
            scatter = max((p - 1) * l + ((p - 1) / p * payload) / b
                          for p, b, l in zip(sizes, bws, lats))
            cross = ring_allreduce_time(payload / min(sizes), len(sizes),
                                        inter, xlat)
            assert legacy == 2.0 * scatter + cross


def test_hierarchical_depth3_recursion():
    """Three levels priced by hand: rack reduce-scatters, pod-level
    shard reduce-scatter, cluster shard ring, and the mirror gathers."""
    payload = 1e4
    rack = CommDomain(bw=4e5, latency=1e-3, size=2)
    pod = CommDomain(bw=2e5, latency=2e-3, children=[rack, rack])
    root = CommDomain(bw=1e5, latency=4e-3, children=[pod, pod])
    rack_rs = 1 * 1e-3 + ((1 / 2) * payload) / 4e5
    pod_rs = 1 * 2e-3 + ((1 / 2) * (payload / 2)) / 2e5
    cross = ring_allreduce_time(payload / 4, 2, 1e5, 4e-3)
    expect = 2.0 * (rack_rs + pod_rs) + cross
    assert hierarchical_allreduce_time(payload, root) == \
        pytest.approx(expect, rel=1e-12)
    # a domain tree with the same links everywhere collapses toward the
    # flat ring's bandwidth term; nesting must never price negative/zero
    assert hierarchical_allreduce_time(payload, root) > 0.0


def test_tree_topology_prices_like_the_comm_tree():
    """Topology routing on a 3-level tree = hand-built CommDomain
    pricing (min'd with the topology-threaded flat ring)."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    payload = 1e3
    bw, lat = TOY["link_bw"], TOY["link_latency"]
    rack = CommDomain(bw=bw, latency=lat, size=2)
    pod = CommDomain(bw=1.5e5, latency=3e-3, children=[rack, rack])
    root = CommDomain(bw=1e5, latency=4e-3, children=[pod, pod])
    hier = hierarchical_allreduce_time(payload, root)
    flat = ring_allreduce_time(payload, 8, min(bw, 1.5e5, 1e5),
                               max(lat, 3e-3, 4e-3))
    assert topo.allreduce_time(payload, profiles) == min(hier, flat)
    # participants inside one rack: plain ring on the node links
    r0 = [p for p in profiles if p.pod == 0 and p.rack == 0]
    assert topo.allreduce_time(payload, r0) == \
        ring_allreduce_time(payload, 2, bw, lat)
    # spanning racks of one pod: two-level pricing, no cluster terms
    p0 = [p for p in profiles if p.pod == 0]
    two = CommDomain(bw=1.5e5, latency=3e-3, children=[rack, rack])
    flat2 = ring_allreduce_time(payload, 4, min(bw, 1.5e5),
                                max(lat, 3e-3))
    assert topo.allreduce_time(payload, p0) == \
        min(hierarchical_allreduce_time(payload, two), flat2)


def test_tree_topology_point_to_point_crosses_levels():
    """Each internal level crossed bottlenecks the transfer and adds its
    hop latency; a same-rack transfer sees only the node links."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    bw, lat = TOY["link_bw"], TOY["link_latency"]
    a, b = profiles[0], profiles[1]          # same rack p0r0
    c = profiles[2]                          # p0r1: same pod, other rack
    d = profiles[4]                          # p1r0: other pod
    assert topo.point_to_point_time(1e3, a, b) == lat + 1e3 / bw
    assert topo.point_to_point_time(1e3, a, c) == \
        (lat + 3e-3) + 1e3 / min(bw, 1.5e5)
    assert topo.point_to_point_time(1e3, a, d) == \
        (lat + 3e-3 + 4e-3 + 3e-3) + 1e3 / min(bw, 1.5e5, 1e5)


def test_tree_topology_level_and_domain_scopes():
    """Windows target one level or one named domain without touching
    the rest; bad scopes fail loudly."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5, pod_bw=1.5e5)
    r0 = [p for p in profiles if p.pod == 0 and p.rack == 0]
    p0 = [p for p in profiles if p.pod == 0]
    base_rack = topo.allreduce_time(1e3, r0)
    base_pod = topo.allreduce_time(1e3, p0)
    base_all = topo.allreduce_time(1e3, profiles)
    # level:1 = the pod domains (paths joining racks): rack-local
    # collectives don't notice, pod- and cluster-spanning ones do
    topo.add_fabric_window(10.0, 1.0, bw_scale=0.1, scope="level:1")
    assert topo.allreduce_time(1e3, r0, now=10.5) == base_rack
    assert topo.allreduce_time(1e3, p0, now=10.5) > base_pod
    assert topo.allreduce_time(1e3, profiles, now=10.5) > base_all
    # domain:p1r0 hits only that rack
    topo.add_fabric_window(20.0, 1.0, bw_scale=0.1, scope="domain:p1r0")
    assert topo.allreduce_time(1e3, r0, now=20.5) == base_rack
    r1 = [p for p in profiles if p.pod == 1 and p.rack == 0]
    assert topo.allreduce_time(1e3, r1, now=20.5) > \
        topo.allreduce_time(1e3, r1, now=0.0)
    with pytest.raises(ValueError, match="unknown domain"):
        topo.add_fabric_window(0.0, 1.0, scope="domain:nope")
    with pytest.raises(ValueError, match="no domains at level"):
        topo.add_fabric_window(0.0, 1.0, scope="level:7")
    with pytest.raises(ValueError, match="scope"):
        topo.add_fabric_window(0.0, 1.0, scope="wat")
    assert set(topo.domain_names()) == {
        "cluster", "p0", "p1", "p0r0", "p0r1", "p1r0", "p1r1"}


def test_edge_scope_prices_per_path_asymmetry():
    """A window on one child's uplink (``scope="edge:<name>"``)
    degrades only collectives and transfers whose route crosses that
    child's single edge into its parent level — sibling paths, traffic
    local to the child, and the other pod stay at clean pricing."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    r00 = [p for p in profiles if p.pod == 0 and p.rack == 0]
    r01 = [p for p in profiles if p.pod == 0 and p.rack == 1]
    p0 = [p for p in profiles if p.pod == 0]
    p1 = [p for p in profiles if p.pod == 1]
    base = {k: topo.allreduce_time(1e3, g) for k, g in
            [("r00", r00), ("r01", r01), ("p0", p0), ("p1", p1),
             ("all", profiles)]}
    a, c, d, e = profiles[0], profiles[2], profiles[4], profiles[6]
    base_ac = topo.point_to_point_time(1e3, a, c)   # p0r0 -> p0r1
    base_ad = topo.point_to_point_time(1e3, a, d)   # p0r0 -> p1r0
    base_de = topo.point_to_point_time(1e3, d, e)   # p1r0 -> p1r1
    topo.add_fabric_window(10.0, 1.0, bw_scale=0.1, scope="edge:p0r0")
    # the degraded edge is p0r0's *uplink*, not its leaf ring
    assert topo.allreduce_time(1e3, r00, now=10.5) == base["r00"]
    # the sibling rack's own traffic never crosses p0r0's edge
    assert topo.allreduce_time(1e3, r01, now=10.5) == base["r01"]
    # pod- and cluster-spanning collectives include p0r0: degraded
    assert topo.allreduce_time(1e3, p0, now=10.5) > base["p0"]
    assert topo.allreduce_time(1e3, profiles, now=10.5) > base["all"]
    # the other pod is untouched, symmetrically for point-to-point
    assert topo.allreduce_time(1e3, p1, now=10.5) == base["p1"]
    assert topo.point_to_point_time(1e3, a, c, now=10.5) > base_ac
    assert topo.point_to_point_time(1e3, a, d, now=10.5) > base_ad
    assert topo.point_to_point_time(1e3, d, e, now=10.5) == base_de
    with pytest.raises(ValueError, match="unknown domain"):
        topo.add_fabric_window(0.0, 1.0, scope="edge:nope")
    with pytest.raises(ValueError, match="no uplink edge"):
        topo.add_fabric_window(0.0, 1.0, scope="edge:cluster")


def test_identity_uplink_window_keeps_symmetric_pricing_bit_identical():
    """The per-path model is structurally guarded: an uplink schedule
    that cannot deviate from the identity must price bit-for-bit like
    the uplink-free fabric, and an *identity-valued* window (scale 1,
    zero latency) must too — the asymmetric code path degenerates
    exactly, so pre-uplink digests never move."""
    def build():
        profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
        return profiles, Topology.from_profiles(
            profiles, inter_bw=1e5, inter_latency=4e-3, pod_bw=1.5e5,
            pod_latency=3e-3)
    profiles, clean = build()
    profiles2, windowed = build()
    windowed.add_fabric_window(0.0, None, bw_scale=1.0,
                               extra_latency=0.0, scope="edge:p0r0")
    for g, g2 in [(profiles, profiles2), (profiles[:4], profiles2[:4]),
                  (profiles[:2], profiles2[:2])]:
        assert clean.allreduce_time(1e3, g, now=0.5) == \
            windowed.allreduce_time(1e3, g2, now=0.5)
    assert clean.point_to_point_time(1e3, profiles[0], profiles[4]) == \
        windowed.point_to_point_time(1e3, profiles2[0], profiles2[4])


def test_explicit_tree_constructor_and_validation():
    tree = FabricDomain(name="root", bw=1e5, latency=1e-3, children=[
        FabricDomain(name="a", nodes=["n0", "n1"]),
        FabricDomain(name="b", nodes=["n2"])])
    topo = Topology(tree=tree)
    assert topo.pods == [["n0", "n1"], ["n2"]]
    assert topo.pod_of("n2") == 1
    with pytest.raises(ValueError, match="not in the topology"):
        topo.pod_of("stranger")
    with pytest.raises(ValueError, match="positive bw"):
        Topology(tree=FabricDomain(name="r", bw=0.0, children=[
            FabricDomain(name="a", nodes=["x"])]))
    with pytest.raises(ValueError, match="more than once"):
        Topology(tree=FabricDomain(name="r", bw=1.0, children=[
            FabricDomain(name="a", nodes=["x"]),
            FabricDomain(name="a", nodes=["y"])]))
    with pytest.raises(ValueError, match="more than one domain"):
        Topology(tree=FabricDomain(name="r", bw=1.0, children=[
            FabricDomain(name="a", nodes=["x"]),
            FabricDomain(name="b", nodes=["x"])]))
    with pytest.raises(ValueError, match="either a tree or"):
        Topology()


def test_preinstalled_fabric_window_reprices_inflight():
    """A congestion window configured directly on the network (no
    scenario events) that opens while the run's only collective is in
    flight must stretch that collective: window edges from the caller's
    schedule re-price in-flight syncs too."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_init_trainers=1, num_outer_steps=1)
    sims = {}
    for congested in (False, True):
        net = NetworkModel()
        if congested:
            # the single sync flies roughly [1ms, 5.3ms); open at 2ms
            net.add_fabric_window(2e-3, 1.0, bw_scale=0.05,
                                  extra_latency=0.1)
        _, inits, streams = _quad_setup(k=1, M=2)
        _, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                                policy="sync", profiles=_profiles(2),
                                network=net)
        sims[congested] = rep
        assert rep.num_syncs == 1
    # launch-time pricing alone would leave sim_time unchanged (~5.3ms);
    # re-pricing the in-flight sync at the window edge dominates it
    assert sims[False].sim_time < 1e-2
    assert sims[True].sim_time > 5e-2
    assert sims[True].comm_time > 10 * sims[False].comm_time


def test_adaptive_rounds_price_stats_collectives():
    """Every adaptive round must end in a priced batch-stats reduction
    (one per completed round per trainer) at the two-phase protocol
    payload; fixed-batch runs must price none — that is what keeps the
    pre-adaptive golden digests byte-identical."""
    _, inits, streams = _quad_setup()
    pool_a, _, rep_a = run_cluster(
        quad_loss, inits, streams,
        dataclasses.replace(BASE, enable_merge=False),
        policy="sync", profiles=_profiles(6))
    assert rep_a.num_stats_syncs == sum(rep_a.rounds.values()) > 0
    stats = [e for e in pool_a.comms.log if e["kind"] == "stats"]
    assert len(stats) == rep_a.num_stats_syncs
    from repro.core.batching import stats_payload_bytes
    # ring bytes accounting: 2(p-1)/p * payload * p over the protocol
    # payload for the 16-dim quadratic
    assert all(e["bytes"] == 2.0 * stats_payload_bytes(16)
               for e in stats)
    assert all(e["time_s"] > 0.0 for e in stats)

    _, inits2, streams2 = _quad_setup()
    pool_f, _, rep_f = run_cluster(
        quad_loss, inits2, streams2,
        dataclasses.replace(BASE, enable_merge=False, adaptive=False),
        policy="sync", profiles=_profiles(6), fixed_batch=4)
    assert rep_f.num_stats_syncs == 0
    assert not any(e["kind"] == "stats" for e in pool_f.comms.log)


def test_fabric_window_reprices_inflight_stats_collective():
    """A congestion window opening while a batch-stats reduction is in
    flight must stretch it (fraction done credited, remainder re-costed
    under the degraded fabric) — stats collectives join the same
    re-pricing registry as outer syncs."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_init_trainers=1, num_outer_steps=1,
                               stats_estimator="microbatch")
    logs = {}
    for congested in (False, True):
        net = NetworkModel()
        if congested:
            # round compute ends ~1ms in; the stats reduction flies
            # ~[1ms, 5.4ms) — open the window mid-flight
            net.add_fabric_window(2e-3, 1.0, bw_scale=0.05,
                                  extra_latency=0.1)
        _, inits, streams = _quad_setup(k=1, M=2)
        pool, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                                   policy="sync", profiles=_profiles(2),
                                   network=net)
        assert rep.num_stats_syncs == 1
        logs[congested] = [e for e in pool.comms.log
                          if e["kind"] == "stats"][0]
    # launch-time pricing alone would leave the stats duration at its
    # clean value; the re-priced remainder dominates it
    assert logs[True]["time_s"] > 5.0 * logs[False]["time_s"]


def test_fabric_window_reprices_inflight_piggyback_collective():
    """A congestion window opening while a *fused* piggyback collective
    (outer params + phase-1 stats vector) is in flight must stretch
    that single collective — the fused payload joins the re-pricing
    registry ONCE, never as separate outer and stats entries, and its
    wire-bytes accounting is invariant to the window."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_init_trainers=1, num_outer_steps=2,
                               stats_estimator="microbatch")
    logs = {}
    for congested in (False, True):
        net = NetworkModel()
        if congested:
            # round 1's fused sync flies roughly [1ms, 5.8ms); open the
            # window mid-flight
            net.add_fabric_window(2e-3, 1.0, bw_scale=0.05,
                                  extra_latency=0.1)
        _, inits, streams = _quad_setup(k=1, M=2)
        pool, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                                   policy="async", profiles=_profiles(2),
                                   network=net)
        kinds = [e["kind"] for e in pool.comms.log]
        assert "outer" not in kinds and "stats" not in kinds
        logs[congested] = [e for e in pool.comms.log
                          if e["kind"] == "piggyback"]
        assert len(logs[congested]) == rep.num_stats_syncs == 2
    # bytes: identical fused payload either way (priced exactly once);
    # time: launch-time pricing alone would keep the clean duration —
    # the re-priced remainder under the degraded fabric dominates it
    assert [e["bytes"] for e in logs[True]] == \
        [e["bytes"] for e in logs[False]]
    assert logs[True][0]["time_s"] > 5.0 * logs[False][0]["time_s"]


def test_async_still_hides_outer_comm_under_adaptive():
    """The outer all-reduce overlaps compute under async, and the stats
    phase no longer even gates the round boundary: its phase-1 vector
    rides the outer sync as a fused ``piggyback`` collective — adaptive
    runs must keep the async < sync clock advantage and pay zero
    standalone stats collectives."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               stats_estimator="microbatch")
    sims, pools = {}, {}
    for policy in ("sync", "async"):
        _, inits, streams = _quad_setup()
        pool, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                                   policy=policy,
                                   profiles=_profiles(6, ratio=2.0))
        sims[policy] = rep
        pools[policy] = pool
    assert sims["async"].sim_time < sims["sync"].sim_time
    assert sims["async"].num_stats_syncs > 0
    assert sims["sync"].num_stats_syncs > 0
    # sync keeps the inline gated stats path (bit-parity with the
    # legacy loop); async fuses every stats phase onto an outer sync
    kinds_sync = {e["kind"] for e in pools["sync"].comms.log}
    kinds_async = {e["kind"] for e in pools["async"].comms.log}
    assert "stats" in kinds_sync and "piggyback" not in kinds_sync
    assert "piggyback" in kinds_async and "stats" not in kinds_async
    n_piggy = sum(e["kind"] == "piggyback"
                  for e in pools["async"].comms.log)
    assert n_piggy == sims["async"].num_stats_syncs


def test_rejects_unknown_policy_and_short_profiles():
    _, inits, streams = _quad_setup()
    with pytest.raises(ValueError, match="policy"):
        run_cluster(quad_loss, inits, streams, BASE, policy="warp")
    with pytest.raises(ValueError, match="profiles"):
        run_cluster(quad_loss, inits, streams, BASE, profiles=_profiles(2))


# ------------------------------------------------------------ policy: sync

def test_sync_policy_matches_legacy_loop_exactly():
    """With merging off, trainers are independent and the sync policy
    must reproduce the host loop bit-for-bit — heterogeneity only moves
    the simulated clock."""
    acfg = dataclasses.replace(BASE, enable_merge=False)
    prob, inits, streams = _quad_setup()
    pool_l, hist_l = train_adloco(quad_loss, inits, streams, acfg,
                                  eval_fn=_eval_fn(prob))
    prob2, inits2, streams2 = _quad_setup()
    pool_c, hist_c, rep = run_cluster(
        quad_loss, inits2, streams2, acfg, policy="sync",
        profiles=_profiles(6, ratio=4.0), eval_fn=_eval_fn(prob2))
    np.testing.assert_allclose(
        np.asarray(pool_l.global_params["x"]),
        np.asarray(pool_c.global_params["x"]), rtol=0, atol=0)
    # every trainer's final eval matches the host loop's (the cluster
    # interleaves records by collective completion, so compare per tid
    # rather than relying on which trainer happened to record last)
    last_by_tid = {}
    for d in hist_c.eval_loss_by_trainer:
        last_by_tid.update(d)
    for tid, v in hist_l.eval_loss_by_trainer[-1].items():
        assert last_by_tid[tid] == pytest.approx(v)
    assert rep.sim_time > 0 and rep.comm_time > 0
    assert len(hist_c.sim_time) == len(hist_c.loss)


def test_sync_policy_matches_legacy_loop_under_topology():
    """Topology + congestion change *time*, never numerics: the sync
    policy must stay bit-identical to the host loop on a 2-pod fabric
    with bursty cross-pod congestion in flight."""
    acfg = dataclasses.replace(BASE, enable_merge=False)
    prob, inits, streams = _quad_setup()
    pool_l, _ = train_adloco(quad_loss, inits, streams, acfg)

    profiles = make_pod_profiles([3, 3], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    _, inits2, streams2 = _quad_setup()
    pool_c, _, rep = run_cluster(
        quad_loss, inits2, streams2, acfg, policy="sync",
        profiles=interleaved, network=topo,
        scenario="bursty_congestion")
    np.testing.assert_allclose(
        np.asarray(pool_l.global_params["x"]),
        np.asarray(pool_c.global_params["x"]), rtol=0, atol=0)
    # the congestion windows actually hit the clock
    assert any(e["kind"] == "fabric" for e in rep.applied_events)
    assert rep.sim_time > 0 and rep.comm_time > 0


def test_elastic_same_seed_and_scenario_is_reproducible():
    """Elastic runs are exactly reproducible: same seed + registered
    scenario => identical report and bit-identical final params."""
    def go():
        profiles = make_pod_profiles([4, 4], ratio=2.0, **TOY)
        interleaved = interleave_pods(profiles)
        topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                      inter_latency=4e-3)
        prob, inits, streams = _quad_setup()
        streams = streams + [QuadStream(prob, 100 + i) for i in range(2)]
        return run_cluster(quad_loss, inits, streams, BASE,
                           policy="elastic", profiles=interleaved,
                           network=topo, scenario="spot_churn")

    pool1, _, rep1 = go()
    pool2, _, rep2 = go()
    assert rep1.summary() == rep2.summary()
    assert rep1.applied_events == rep2.applied_events
    np.testing.assert_allclose(
        np.asarray(pool1.global_params["x"]),
        np.asarray(pool2.global_params["x"]), rtol=0, atol=0)


def test_sync_cluster_merges_contract_pool():
    _, inits, streams = _quad_setup()
    pool, hist, rep = run_cluster(quad_loss, inits, streams, BASE,
                                  policy="sync", profiles=_profiles(6))
    assert pool.k < 3
    assert any(e["kind"] == "merge" for e in pool.comms.log)
    assert any(e["kind"] == "merge" for e in rep.applied_events)


# ------------------------------------------------------- straggler timing

def test_straggler_changes_wallclock_not_loss():
    """Jitter and slowdown events stretch the simulated clock; in the
    sync policy the parameter trajectory is untouched."""
    acfg = dataclasses.replace(BASE, enable_merge=False)
    runs = {}
    for jitter in (0.0, 0.5):
        prob, inits, streams = _quad_setup()
        scen = [] if jitter == 0.0 else [
            ClusterEvent(time=0.0, kind="slowdown", node=0, factor=4.0,
                         duration=1e9)]
        pool, hist, rep = run_cluster(
            quad_loss, inits, streams, acfg, policy="sync",
            profiles=_profiles(6, jitter=jitter), scenario=scen,
            eval_fn=_eval_fn(prob))
        runs[jitter] = (pool, hist, rep)
    np.testing.assert_allclose(
        np.asarray(runs[0.0][0].global_params["x"]),
        np.asarray(runs[0.5][0].global_params["x"]), rtol=0, atol=0)
    # straggler run must be measurably slower on the simulated clock
    assert runs[0.5][2].sim_time > 1.2 * runs[0.0][2].sim_time


# ------------------------------------------------------------ policy: async

def test_async_matches_sync_loss_within_tolerance():
    """ACCO-style overlap applies pseudo-gradients one round late; the
    trajectory may differ but the converged loss must agree."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_outer_steps=14)
    finals = {}
    for policy in ("sync", "async"):
        prob, inits, streams = _quad_setup()
        ev = _eval_fn(prob)
        pool, hist, rep = run_cluster(
            quad_loss, inits, streams, acfg, policy=policy,
            profiles=_profiles(6, ratio=2.0), eval_fn=ev)
        finals[policy] = ev(pool.global_params)
    assert finals["async"] == pytest.approx(finals["sync"], rel=0.15)


@pytest.mark.slow
def test_async_matches_sync_loss_on_tiny_lm():
    import jax

    from repro import models
    from repro.configs import get_config, reduced
    from repro.data import MarkovTokenStream

    cfg = reduced(get_config("microllama-300m"))
    acfg = AdLoCoConfig(num_outer_steps=3, num_inner_steps=3, lr_inner=3e-4,
                        lr_outer=0.5, outer_momentum=0.5, nodes_per_gpu=2,
                        num_init_trainers=1, initial_batch_size=2,
                        enable_merge=False, max_batch=8, stats_probe_size=8)
    loss_fn = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731
    held = MarkovTokenStream(cfg.vocab_size, 32, shard=99,
                             seed=0).next_batch(8)
    eval_fn = lambda p: float(loss_fn(p, held)[0])  # noqa: E731
    finals = {}
    for policy in ("sync", "async"):
        inits = [models.init_params(cfg, jax.random.PRNGKey(0))]
        streams = [MarkovTokenStream(cfg.vocab_size, 32, shard=i, seed=0)
                   for i in range(2)]
        pool, hist, _ = run_cluster(loss_fn, inits, streams, acfg,
                                    policy=policy, profiles=_profiles(2),
                                    eval_fn=eval_fn)
        finals[policy] = eval_fn(pool.global_params)
    assert np.isfinite(list(finals.values())).all()
    assert finals["async"] == pytest.approx(finals["sync"], rel=0.1)


def test_delay_compensation_fixes_high_momentum_async():
    """Regression for the documented staleness bug: outer_momentum=0.9
    under the async policy's one-round-stale application is underdamped
    — the run stalls far above the noise floor.  Delay compensation
    scales the momentum by the measured staleness of each applied
    pseudo-gradient (``mu / (1 + delay)``) and restores convergence."""
    finals, floor = {}, None
    for comp in (False, True):
        acfg = dataclasses.replace(BASE, enable_merge=False,
                                   num_outer_steps=14, lr_outer=1.0,
                                   outer_momentum=0.9,
                                   delay_compensation=comp)
        prob, inits, streams = _quad_setup()
        ev = _eval_fn(prob)
        pool, _, _ = run_cluster(quad_loss, inits, streams, acfg,
                                 policy="async",
                                 profiles=_profiles(6, ratio=2.0),
                                 eval_fn=ev)
        finals[comp] = ev(pool.global_params)
        floor = 0.5 * prob.noise ** 2
    # uncompensated 0.9 oscillates: still > 2x the noise floor after 14
    # outer rounds; compensated lands on the floor
    assert finals[False] > 2.0 * floor
    assert finals[True] < 1.1 * floor


def test_delay_compensation_is_identity_at_zero_delay():
    """Sync applies pseudo-gradients at delay 0, where the compensated
    optimizer is bit-equal to plain Nesterov — flipping the flag must
    not move a single bit of a synchronous trajectory."""
    outs = {}
    for comp in (False, True):
        acfg = dataclasses.replace(BASE, enable_merge=False,
                                   outer_momentum=0.9,
                                   delay_compensation=comp)
        _, inits, streams = _quad_setup()
        pool, _, _ = run_cluster(quad_loss, inits, streams, acfg,
                                 policy="sync", profiles=_profiles(6))
        outs[comp] = np.asarray(pool.global_params["x"])
    np.testing.assert_allclose(outs[False], outs[True], rtol=0, atol=0)


def test_async_hides_communication_time():
    """Same numeric work, but the async clock must come in under sync
    whenever collectives cost nonzero time."""
    acfg = dataclasses.replace(BASE, enable_merge=False)
    sims = {}
    for policy in ("sync", "async"):
        _, inits, streams = _quad_setup()
        _, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                                policy=policy,
                                profiles=_profiles(6, ratio=2.0))
        sims[policy] = rep
    assert sims["async"].sim_time < sims["sync"].sim_time
    assert sims["async"].comm_time > 0


# --------------------------------------------------------- policy: elastic

def _elastic_setup(k=3, M=2, spare=1):
    prob, inits, streams = _quad_setup(k=k, M=M)
    spare_streams = [QuadStream(prob, 100 + i) for i in range(spare * M)]
    return prob, inits, streams + spare_streams


def test_elastic_join_leave_keeps_pool_invariants():
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_outer_steps=10)
    prob, inits, streams = _elastic_setup()
    profiles = _profiles(8, ratio=2.0)
    # time the events inside the run: a sync run of the same shape takes
    # ~10 rounds; leave early, join midway
    scen = [ClusterEvent(time=1e-3, kind="leave"),
            ClusterEvent(time=5e-3, kind="join")]
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy="elastic",
        profiles=profiles, scenario=scen, eval_fn=_eval_fn(prob))

    kinds = [e["kind"] for e in rep.applied_events]
    assert "leave" in kinds and "join" in kinds
    # pool size: 3 initial - 1 leave + 1 join
    assert pool.k == 3
    # stream ownership: every stream owned by exactly one trainer, no
    # trainer hoards more than its M shards (the scripted leave
    # returned the leaver's slice to the spare pool, where the joiner
    # could draw it back), and nothing was invented out of thin air
    owned = [id(s) for tr in pool.trainers for s in tr.streams]
    assert len(owned) == len(set(owned))
    assert all(len(tr.streams) == 2 for tr in pool.trainers)
    assert set(owned) <= {id(s) for s in streams}
    # the joiner trained and is attributable in history
    join_tid = next(e["tid"] for e in rep.applied_events
                    if e["kind"] == "join")
    assert rep.rounds.get(join_tid, 0) > 0
    assert any(join_tid in d for d in hist.eval_loss_by_trainer)
    # elastic run still converges
    assert hist.eval_loss[-1] < hist.eval_loss[0]


def test_preemption_returns_leaver_capacity_for_regrowth():
    """Regression for the stream-hoarding leave: a scripted (preempted)
    leave used to union the leaver's data shards onto the survivor and
    only free its nodes, so a later join found ``free_streams``
    exhausted (``join_skipped``) while nodes sat idle — a preemption
    storm permanently shrank the pool.  The leave now returns the full
    capacity slice, so with *zero* provisioned spares the pool can
    still re-grow from reclaimed capacity alone."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_outer_steps=10)
    prob, inits, streams = _elastic_setup(spare=0)
    scen = [ClusterEvent(time=1e-3, kind="leave"),
            ClusterEvent(time=5e-3, kind="join")]
    pool, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                               policy="elastic", profiles=_profiles(6),
                               scenario=scen)
    kinds = [e["kind"] for e in rep.applied_events]
    assert kinds.count("leave") == 1
    assert "join" in kinds and "join_skipped" not in kinds
    assert pool.k == 3
    assert all(len(tr.streams) == 2 for tr in pool.trainers)


def test_autoscale_shrink_consolidates_streams_on_survivor():
    """The flip side of the reclamation fix: a leave *decided by the
    autoscale policy* is a consolidation, not an eviction — the
    survivor keeps the unioned shards (this is what the pinned
    ``autoscale_ramp`` golden trajectory encodes)."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_outer_steps=10)
    prob, inits, streams = _elastic_setup(spare=0)
    ev = ClusterEvent(time=1e-3, kind="leave", autoscaled=True)
    pool, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                               policy="elastic", profiles=_profiles(6),
                               scenario=[ev])
    assert pool.k == 2
    # survivor absorbed the leaver's shards; nothing went to spares
    sizes = sorted(len(tr.streams) for tr in pool.trainers)
    assert sizes == [2, 4]


def test_elastic_leave_requires_survivor():
    """The last trainer never leaves (the event is a no-op)."""
    acfg = dataclasses.replace(BASE, num_init_trainers=1, enable_merge=False,
                               num_outer_steps=4)
    _, inits, streams = _quad_setup(k=1, M=2)
    scen = [ClusterEvent(time=0.0, kind="leave")]
    pool, _, rep = run_cluster(quad_loss, inits[:1], streams[:2], acfg,
                               policy="elastic", profiles=_profiles(2),
                               scenario=scen)
    assert pool.k == 1
    assert not any(e["kind"] == "leave" for e in rep.applied_events)


def test_elastic_join_without_spares_is_noop():
    acfg = dataclasses.replace(BASE, enable_merge=False, num_outer_steps=4)
    _, inits, streams = _quad_setup()
    scen = [ClusterEvent(time=0.0, kind="join")]
    pool, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                               policy="elastic", profiles=_profiles(6),
                               scenario=scen)
    assert pool.k <= 3
    assert not any(e["kind"] == "join" for e in rep.applied_events)


def test_leave_mid_flight_abandons_dispatched_outer():
    """A leave landing between an outer dispatch and its fold must
    abandon the in-flight handle cleanly: the absorbed trainer's
    collective span is truncated at the preemption time, no stale
    result folds into the merged pool, and the run still converges."""
    from repro.cluster.trace import Trace
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_outer_steps=6)
    prob, inits, streams = _quad_setup()
    tr = Trace()
    # trainer 0's round-1 outer sync flies roughly [5.6ms, 10ms)
    scen = [ClusterEvent(time=6e-3, kind="leave")]
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy="elastic",
        profiles=_profiles(6, ratio=2.0), scenario=scen,
        eval_fn=_eval_fn(prob), trace=tr)
    leave = next(e for e in rep.applied_events if e["kind"] == "leave")
    assert leave["time"] == pytest.approx(6e-3)
    # the preempted collective is visible in the trace: an outer span
    # cut at the leave time instead of running to its priced end
    cut = [s for s in tr.spans if s.kind == "outer"
           and ("left" in s.payload or "absorbed_leave" in s.payload)]
    assert cut and all(s.t1 == pytest.approx(6e-3) for s in cut)
    assert pool.k == 2
    assert np.isfinite(np.asarray(pool.global_params["x"])).all()
    assert hist.eval_loss[-1] < 0.5 * hist.eval_loss[0]


# ------------------------------------------------------ time-to-target

def test_async_reduces_time_to_target_under_heterogeneity():
    """The acceptance headline: with node speeds differing by >= 2x,
    async must hit the target loss strictly earlier on the sim clock."""
    acfg = dataclasses.replace(BASE, enable_merge=False,
                               num_outer_steps=12)
    t2t = {}
    for policy in ("sync", "async"):
        prob, inits, streams = _quad_setup()
        _, hist, _ = run_cluster(
            quad_loss, inits, streams, acfg, policy=policy,
            profiles=_profiles(6, ratio=2.0), eval_fn=_eval_fn(prob))
        target = 0.5 * prob.noise ** 2 * 1.25
        t2t[policy] = next((s for v, s in zip(hist.eval_loss,
                                              hist.sim_time)
                            if v <= target), None)
    assert t2t["sync"] is not None and t2t["async"] is not None
    assert t2t["async"] < t2t["sync"]


# ------------------------------------- MIT merge/consolidate invariants

def _mit_pool(xs, breqs):
    """Tiny pool fixture: trainer i holds params {"x": xs[i]}, requested
    batch breqs[i], and two named data shards."""
    trainers = [TrainerState(tid=i,
                             params={"x": jnp.asarray(x, jnp.float32)},
                             outer_opt_state=(), inner_opt_states=[()],
                             requested_batch=b,
                             streams=[f"s{i}a", f"s{i}b"])
                for i, (x, b) in enumerate(zip(xs, breqs))]
    return TrainerPoolState(trainers=trainers)


def test_do_merge_invariants():
    pool = _mit_pool([[1.0], [3.0], [5.0]], [4, 2, 6])
    ids = check_merge([t.requested_batch for t in pool.trainers], 2)
    assert ids == [1, 0]                    # the two smallest batches
    pool = do_merge(pool, ids, step=7)
    # pool contracts by |S| - 1
    assert pool.k == 2
    rep = pool.trainers[0]
    # representative = largest requested batch in the merge set
    assert rep.tid == 0
    # batch-weighted average of the merge set only
    np.testing.assert_allclose(np.asarray(rep.params["x"]),
                               (4 * 1.0 + 2 * 3.0) / 6, rtol=1e-6)
    # representative inherits the union of the merged shards
    assert rep.streams == ["s0a", "s0b", "s1a", "s1b"]
    # bystander untouched
    assert pool.trainers[1].tid == 2
    assert pool.trainers[1].streams == ["s2a", "s2b"]
    # comms meter charged one merge among |S| participants
    rec = pool.comms.log[-1]
    assert rec["kind"] == "merge" and rec["participants"] == 2
    assert rec["step"] == 7 and rec["bytes"] > 0
    assert pool.comms.events == 1


def test_do_merge_whole_pool_via_clamped_w():
    """check_merge(w > k) clamps to the full pool; do_merge then
    contracts k -> 1 and averages everyone."""
    pool = _mit_pool([[1.0], [2.0], [9.0]], [1, 1, 1])
    ids = check_merge([1, 1, 1], 99)
    assert ids == [0, 1, 2]
    pool = do_merge(pool, ids, step=0)
    assert pool.k == 1
    np.testing.assert_allclose(np.asarray(pool.trainers[0].params["x"]),
                               4.0, rtol=1e-6)


def test_consolidate_invariants():
    pool = _mit_pool([[2.0], [6.0]], [1, 3])
    pool = consolidate(pool, step=9)
    np.testing.assert_allclose(np.asarray(pool.global_params["x"]),
                               (1 * 2.0 + 3 * 6.0) / 4, rtol=1e-6)
    rec = pool.comms.log[-1]
    assert rec["kind"] == "consolidate" and rec["participants"] == 2
    assert rec["bytes"] > 0
    assert param_bytes(pool.global_params) > 0
    # a single-trainer consolidate is free: no collective, no record
    solo = _mit_pool([[7.0]], [5])
    solo = consolidate(solo, step=9)
    np.testing.assert_allclose(np.asarray(solo.global_params["x"]), 7.0)
    assert solo.comms.log == []


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def test_property_do_merge_weighted_average(data):
        k = data.draw(st.integers(2, 6))
        xs = data.draw(st.lists(st.floats(-5, 5), min_size=k,
                                max_size=k))
        breqs = data.draw(st.lists(st.integers(1, 50), min_size=k,
                                   max_size=k))
        w = data.draw(st.integers(2, k))
        pool = _mit_pool([[x] for x in xs], breqs)
        ids = check_merge(breqs, w)
        S = list(ids)
        expected = (sum(breqs[i] * xs[i] for i in S)
                    / sum(breqs[i] for i in S))
        rep_tid = max(S, key=lambda i: (breqs[i], -i))
        pool = do_merge(pool, ids, step=0)
        assert pool.k == k - (len(ids) - 1)
        rep = next(t for t in pool.trainers if t.tid == rep_tid)
        np.testing.assert_allclose(np.asarray(rep.params["x"]),
                                   expected, rtol=1e-5, atol=1e-5)
        # stream multiset conserved across the union
        owned = sorted(s for t in pool.trainers for s in t.streams)
        assert owned == sorted(f"s{i}{c}" for i in range(k)
                               for c in "ab")
