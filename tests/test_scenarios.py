"""Golden-trace regression tests for the scenario registry.

Every registered scenario, run with a fixed seed on a canonical
cluster, must reproduce a stored digest of its ``ClusterReport`` —
summary *and* applied events — so a scheduler or cost-model refactor
cannot silently change simulated behavior.  The original five
scenarios run on the PR 2 fixture (2-pod topology; their digests are
untouched by the n-level fabric refactor — the differential guarantee),
and the co-scripted scenarios run on a 3-level rack/pod/cluster tree.
The harness pins ``fixed_batch`` + ``adaptive=False`` so simulated
timings are pure Python float arithmetic (no jax numerics in the
digest) and the goldens hold across platforms.

If a change to the runtime/cost models is *intended* to move these
digests, regenerate the stored values with

    PYTHONPATH=src python -m pytest tests/test_scenarios.py --update-goldens

and commit the resulting ``tests/goldens/scenarios.json`` diff — that
diff is the reviewable record of the behavior change.
"""
import dataclasses
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco
from repro.cluster import (BandAutoscale, ClusterEvent, ClusterSpec,
                           Topology, interleave_pods, list_scenarios,
                           make_pod_profiles, make_rack_profiles,
                           run_cluster)
from repro.cluster.scenarios import build_scenario

from tests.test_adloco_integration import QuadStream, _quad_setup, quad_loss

TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)

# fixed_batch + adaptive=False: timings decouple from jax numerics, so
# the digests are pure-Python-float deterministic
ACFG = AdLoCoConfig(num_outer_steps=8, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=3, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32,
                    enable_merge=False, adaptive=False)

#: adaptive arms of the golden suite: run with adaptive batching +
#: switch mode on (microbatch estimator — deterministic jax numerics
#: feed the batch decisions, and batch ints feed the clock), and their
#: digests additionally pin the per-round batch/plan trajectory and the
#: priced stats-reduction count.  12 outer rounds (vs the fixed-batch
#: harness's 8): async piggybacking makes every plan one round stale,
#: so the ramp needs the extra rounds to cross the switch threshold
#: and actually execute an "accum" round inside the run
ACFG_ADAPTIVE = dataclasses.replace(ACFG, adaptive=True,
                                    stats_estimator="microbatch",
                                    num_outer_steps=12,
                                    max_global_batch=256)

#: stored digests: GOLDEN = the PR 2 fixture (2-pod topology), pinned
#: across both the n-level fabric refactor and the execution-backend
#: split (neither may silently re-price them); GOLDEN3 = the co-scripted
#: scenarios on the 3-level rack/pod/cluster fixture; GOLDENA = the
#: adaptive-batching scenarios (2-pod fixture, async policy, batch ramp
#: + stats collectives in the clock); GOLDENM = the merge-enabled
#: drifted-cluster scenario (round-tagged merges skipping laggards);
#: GOLDENAS = the autoscaled adaptive scenarios (elastic policy +
#: BandAutoscale + k_correct=3 predicted growth — the digest pins the
#: policy's scripted joins/leaves and the predictor's round decisions).
#: The values live in tests/goldens/scenarios.json so
#: ``--update-goldens`` can rewrite them mechanically.
GOLDENS_PATH = pathlib.Path(__file__).parent / "goldens" / "scenarios.json"
_STORED = json.loads(GOLDENS_PATH.read_text())
GOLDEN = _STORED["GOLDEN"]
GOLDEN3 = _STORED["GOLDEN3"]
GOLDENA = _STORED["GOLDENA"]
GOLDENM = _STORED["GOLDENM"]
GOLDENAS = _STORED["GOLDENAS"]

#: adaptive arms whose digests also pin the batch/plan trajectory
_TRAJ_PINNED = set(GOLDENA) | set(GOLDENAS)

UPDATE_CMD = ("PYTHONPATH=src python -m pytest tests/test_scenarios.py "
              "--update-goldens")


def _group_of(name: str) -> str:
    return ("GOLDENAS" if name in GOLDENAS
            else "GOLDENM" if name in GOLDENM
            else "GOLDENA" if name in GOLDENA
            else "GOLDEN3" if name in GOLDEN3 else "GOLDEN")


def _write_golden(name: str, digest: str) -> None:
    stored = json.loads(GOLDENS_PATH.read_text())
    stored[_group_of(name)][name] = digest
    GOLDENS_PATH.write_text(json.dumps(stored, indent=2, sort_keys=True)
                            + "\n")


def _run(name):
    """PR 2 scenario harness: 2 pods x 5 nodes at 2x pod speed ratio,
    interleaved so every trainer's M=2 workers span both pods (outer
    syncs always cross the bottleneck), 2 spare trainers' worth of
    nodes/streams for joiners."""
    profiles = make_pod_profiles([5, 5], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(4)]
    return run_cluster(quad_loss, inits, streams, ACFG, policy="elastic",
                       profiles=interleaved, network=topo, scenario=name,
                       fixed_batch=4)


def _tree_cluster():
    """3-level fixture: 2 pods x 2 racks x 2 nodes, pod 1 at half speed,
    interleaved so every trainer's M=2 workers span both pods — each
    outer sync crosses every fabric level."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    return interleaved, topo


def _run3(name):
    interleaved, topo = _tree_cluster()
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(2)]
    return run_cluster(quad_loss, inits, streams, ACFG, policy="elastic",
                       profiles=interleaved, network=topo, scenario=name,
                       fixed_batch=4)


def _run_merge(name):
    """Merge-enabled drifted harness: the PR 2 fixture with
    ``enable_merge=True`` under the elastic policy — the scenario's
    slowdowns drift one trainer past ``merge_drift_window``, so the
    round-tagged merge fires on time among the others and records the
    laggard in its ``skipped`` list (the digest pins that).
    ``merge_frequency=6`` gives the 8x-slowed trainer time to fall
    several rounds behind by the first merge round (at merge round 3 it
    would only be one round back — still inside the window)."""
    profiles = make_pod_profiles([5, 5], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(4)]
    acfg = dataclasses.replace(ACFG, enable_merge=True, merge_frequency=6)
    return run_cluster(quad_loss, inits, streams, acfg, policy="elastic",
                       profiles=interleaved, network=topo, scenario=name,
                       fixed_batch=4)


def _run_adaptive(name):
    """Adaptive harness: the PR 2 2-pod fixture under the async policy
    with the batch ramp on — every round prices a stats reduction and
    batch growth stretches the roofline compute, so the digest pins the
    whole adaptive scheduling surface (trajectory included)."""
    profiles = make_pod_profiles([5, 5], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    return run_cluster(quad_loss, inits, streams, ACFG_ADAPTIVE,
                       policy="async", profiles=interleaved, network=topo,
                       scenario=name)


def _run_autoscale(name):
    """Autoscale harness: 2-pod fixture, elastic policy, BandAutoscale
    co-scaling the pool with the batch ramp and ``k_correct=3``
    predicted growth (the exact stats reduction every third round).
    The initial batch is below the band so the policy first *shrinks*
    the pool, then rebuilds it join by join as the ramp crosses ``hi``
    — the digest pins the whole decision trajectory, scripted event
    prices included.  Invoked through ``ClusterSpec`` so the golden
    suite also pins the spec path's equivalence to the legacy kwargs."""
    profiles = make_pod_profiles([6, 6], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=2, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(8)]
    acfg = dataclasses.replace(ACFG_ADAPTIVE, k_correct=3)
    spec = ClusterSpec(policy="elastic", profiles=interleaved,
                       network=topo, scenario=name,
                       autoscale=BandAutoscale(lo=2.0, hi=8.0,
                                               cooldown_rounds=2))
    return run_cluster(quad_loss, inits, streams, acfg, spec=spec)


def _trace(rep, hist=None):
    t = {"summary": rep.summary(), "events": rep.applied_events}
    if hist is not None:
        # adaptive arms: the per-round batch/plan trajectory and the
        # stats-reduction count are part of the pinned behavior
        t["stats_syncs"] = rep.num_stats_syncs
        t["batches"] = hist.requested_batches
        t["modes"] = hist.modes
    return t


def _digest(rep, hist=None) -> str:
    blob = json.dumps(_trace(rep, hist), sort_keys=True, default=float)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


_MEMO = {}


def _run_by_group(name):
    if name in GOLDENAS:
        return _run_autoscale(name)
    if name in GOLDENM:
        return _run_merge(name)
    if name in GOLDENA:
        return _run_adaptive(name)
    return _run3(name) if name in GOLDEN3 else _run(name)


def _memo_run(name):
    if name not in _MEMO:
        _MEMO[name] = _run_by_group(name)
    return _MEMO[name]


ALL_NAMES = (sorted(GOLDEN) + sorted(GOLDEN3) + sorted(GOLDENA)
             + sorted(GOLDENM) + sorted(GOLDENAS))


@pytest.mark.parametrize("name", ALL_NAMES)
def test_scenario_matches_golden_trace(name, request):
    _, hist, rep = _memo_run(name)
    golden = _STORED[_group_of(name)][name]
    digest = _digest(rep, hist if name in _TRAJ_PINNED else None)
    if digest == golden:
        return
    if request.config.getoption("--update-goldens"):
        _write_golden(name, digest)
        pytest.skip(f"golden for {name!r} updated: {golden} -> {digest}; "
                    f"commit tests/goldens/scenarios.json")
    pytest.fail(
        f"scenario {name!r} produced a different event/timing trace\n"
        f"  stored digest:   {golden}\n"
        f"  current digest:  {digest}\n"
        f"If this behavior change is intended, regenerate the stored "
        f"digests with:\n  {UPDATE_CMD}\n"
        f"and commit the tests/goldens/scenarios.json diff.\n"
        f"Trace: {_trace(rep, hist if name in _TRAJ_PINNED else None)}")


def test_every_registered_scenario_has_a_golden():
    """Registering a scenario without pinning its trace defeats the
    regression net — add a digest here when adding a generator."""
    assert sorted(list_scenarios()) == sorted({**GOLDEN, **GOLDEN3,
                                               **GOLDENA, **GOLDENM,
                                               **GOLDENAS})


@pytest.mark.parametrize("name", ALL_NAMES)
def test_scenario_is_deterministic(name):
    """Same seed + scenario => identical ClusterReport, field by field
    (the acceptance criterion behind the golden digests)."""
    _, hist1, rep1 = _memo_run(name)
    _, hist2, rep2 = _run_by_group(name)
    assert rep1.summary() == rep2.summary()
    assert rep1.applied_events == rep2.applied_events
    if name in _TRAJ_PINNED:
        # the adaptive trajectory is part of the pinned behavior
        assert hist1.requested_batches == hist2.requested_batches
        assert hist1.modes == hist2.modes
        assert rep1.num_stats_syncs == rep2.num_stats_syncs


def test_scenarios_exercise_their_event_kinds():
    """The canonical harness must actually reach each scenario's events
    (a scenario whose events land after the run drains tests nothing)."""
    expected = {"bursty_congestion": {"fabric"},
                "pod_partition": {"fabric"},
                "flash_crowd_join": {"join"},
                "spot_churn": {"leave", "join"},
                "correlated_pod_failure": {"slowdown", "fabric"},
                "diurnal_congestion": {"fabric"},
                "rack_flap": {"fabric"},
                "straggler_cascade": {"slowdown", "fabric"},
                "adaptive_ramp": set(),
                "congested_adaptive": {"fabric"},
                "drifted_merge": {"slowdown"},
                # the pool dynamics come from the autoscale policy, not
                # the scripted stream: the ramp crosses the band so the
                # policy must both shrink (early small batch) and grow
                "autoscale_ramp": {"autoscale", "join", "leave"},
                "preemption_storm_growth": {"autoscale", "join", "leave"}}
    assert set(expected) == \
        (set(GOLDEN) | set(GOLDEN3) | set(GOLDENA)
         | set(GOLDENM) | set(GOLDENAS)) - {"baseline"}
    for name, kinds in expected.items():
        _, _, rep = _memo_run(name)
        assert kinds <= {e["kind"] for e in rep.applied_events}


def test_adaptive_scenarios_actually_ramp_and_price_stats():
    """The adaptive arms must exercise what they claim: batches grow,
    switch mode engages, every round prices a stats reduction, and the
    congestion window lands while the ramp is still in flight."""
    pool, hist, rep = _memo_run("adaptive_ramp")
    firsts = [bs[0] for bs in hist.requested_batches]
    assert firsts[-1] > firsts[0]
    assert any(m == "accum" for ms in hist.modes for m in ms)
    assert rep.num_stats_syncs > 0
    # async + adaptive: every stats phase rides a fused "piggyback"
    # collective on the outer sync — no standalone stats entries at all
    stats_log = [e for e in pool.comms.log if e["kind"] == "piggyback"]
    assert len(stats_log) == rep.num_stats_syncs
    assert not [e for e in pool.comms.log if e["kind"] == "stats"]
    assert all(e["time_s"] > 0.0 for e in stats_log)
    _, hist_c, rep_c = _memo_run("congested_adaptive")
    window = next(e for e in rep_c.applied_events if e["kind"] == "fabric")
    assert window["time"] < rep_c.sim_time
    # congestion + re-priced collectives cost strictly more wire time,
    # and — because async plans fold when the (stretched) collective
    # lands — the congested run's batch decisions arrive late and
    # starve the ramp: it never reaches the clean run's peak batch
    assert rep_c.comm_time > rep.comm_time
    peak = max(b for bs in hist.requested_batches for b in bs)
    peak_c = max(b for bs in hist_c.requested_batches for b in bs)
    assert peak_c < peak


def test_drifted_merge_skips_the_laggard():
    """The merge-semantics fix, end to end: the drifted trainer must be
    recorded in the merge's ``skipped`` list and survive untouched,
    while the up-to-date trainers merge on time — the old behavior
    (stall the merge until the slowest trainer catches up, then fold
    its rounds-stale params into the pool) is gone."""
    pool, _, rep = _memo_run("drifted_merge")
    merges = [e for e in rep.applied_events if e["kind"] == "merge"]
    assert merges, f"no merge fired: {rep.applied_events}"
    first = merges[0]
    # the merge is round-tagged and fires at its scheduled round
    # (harness merge_frequency=6), not whenever the laggard catches up
    assert first["round"] == 6
    # the slowed trainer (nodes 2,3 -> tid 1) drifted past the window
    # and was skipped, not merged
    assert 1 in first["skipped"]
    assert 1 not in first["merged"]
    # skipping is not dying: the laggard is still in the pool
    assert any(t.tid == 1 for t in pool.trainers)


def test_build_scenario_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown scenario"):
        build_scenario("nope")
    with pytest.raises(ValueError, match="unknown scenario"):
        _run("nope")


def test_spot_churn_seed_controls_stream():
    a = build_scenario("spot_churn", seed=0)
    b = build_scenario("spot_churn", seed=0)
    c = build_scenario("spot_churn", seed=7)
    assert [dataclasses.astuple(e) for e in a] == \
        [dataclasses.astuple(e) for e in b]
    assert [e.time for e in a] != [e.time for e in c]


def test_diurnal_schedule_traces_the_cosine():
    """The piecewise-constant windows must actually dip to the trough
    and recover: scale 1.0-ish at the period edges, `depth` at the
    middle, symmetric."""
    evs = build_scenario("diurnal_congestion", period=0.08, depth=0.3,
                         cycles=1, steps=8)
    scales = [e.bw_scale for e in evs]
    assert len(scales) == 8
    assert min(scales) >= 0.3 and max(scales) <= 1.0
    assert min(scales) == pytest.approx(scales[3]) == pytest.approx(
        scales[4])                   # trough at mid-period
    assert scales[0] == max(scales)
    np.testing.assert_allclose(scales, scales[::-1], rtol=1e-12)
    # windows tile the period with no gaps
    for a, b in zip(evs, evs[1:]):
        assert b.time == pytest.approx(a.time + a.duration)


def test_rack_flap_hits_only_the_named_rack():
    """The flapping rack's windows must leave every other domain's
    pricing untouched — the point of per-domain schedules."""
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5, pod_bw=1.5e5)
    for ev in build_scenario("rack_flap", domain="p0r0"):
        topo.add_fabric_window(ev.time, ev.duration, bw_scale=ev.bw_scale,
                               extra_latency=ev.extra_latency,
                               scope=ev.scope)
    inside = build_scenario("rack_flap", domain="p0r0")[0].time
    a0, a1 = profiles[0], profiles[1]          # p0r0 nodes
    b0, b1 = profiles[2], profiles[3]          # p0r1 nodes
    c0, c1 = profiles[4], profiles[5]          # p1r0 nodes
    quiet = Topology.from_profiles(profiles, inter_bw=1e5, pod_bw=1.5e5)
    # flapped rack slows down...
    assert topo.allreduce_time(1e3, [a0, a1], now=inside) > \
        quiet.allreduce_time(1e3, [a0, a1], now=inside)
    # ...sibling rack and the other pod do not
    assert topo.allreduce_time(1e3, [b0, b1], now=inside) == \
        quiet.allreduce_time(1e3, [b0, b1], now=inside)
    assert topo.allreduce_time(1e3, [c0, c1], now=inside) == \
        quiet.allreduce_time(1e3, [c0, c1], now=inside)
    # between bursts the flapped rack is nominal again
    evs = build_scenario("rack_flap", domain="p0r0")
    between = evs[0].time + evs[0].duration + 1e-6
    assert topo.allreduce_time(1e3, [a0, a1], now=between) == \
        quiet.allreduce_time(1e3, [a0, a1], now=between)


def test_congestion_slows_sync_but_async_hides_it():
    """The fabric windows must actually bite: under the sync policy the
    congested run is strictly slower on the simulated clock than the
    baseline, and the async policy recovers most of the gap."""
    sims = {}
    for name in ("baseline", "bursty_congestion"):
        for policy in ("sync", "async"):
            profiles = make_pod_profiles([3, 3], ratio=1.0, **TOY)
            interleaved = interleave_pods(profiles)
            topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                          inter_latency=4e-3)
            _, inits, streams = _quad_setup(k=3, M=2)
            _, _, rep = run_cluster(quad_loss, inits, streams, ACFG,
                                    policy=policy, profiles=interleaved,
                                    network=topo, scenario=name,
                                    fixed_batch=4)
            sims[(name, policy)] = rep.sim_time
    assert sims[("bursty_congestion", "sync")] > \
        1.05 * sims[("baseline", "sync")]
    sync_overhead = (sims[("bursty_congestion", "sync")]
                     - sims[("baseline", "sync")])
    async_overhead = (sims[("bursty_congestion", "async")]
                      - sims[("baseline", "async")])
    assert async_overhead < sync_overhead


def test_sync_policy_matches_legacy_loop_under_tree_fabric():
    """3-level fabric + an open congestion window change *time*, never
    numerics: the sync policy must stay bit-identical to the host loop
    while a correlated pod failure is degrading the cluster level."""
    acfg = dataclasses.replace(ACFG, adaptive=True)
    prob, inits, streams = _quad_setup()
    pool_l, _ = train_adloco(quad_loss, inits, streams, acfg)

    interleaved, topo = _tree_cluster()
    _, inits2, streams2 = _quad_setup()
    pool_c, _, rep = run_cluster(
        quad_loss, inits2, streams2, acfg, policy="sync",
        profiles=interleaved, network=topo,
        scenario="correlated_pod_failure")
    np.testing.assert_allclose(
        np.asarray(pool_l.global_params["x"]),
        np.asarray(pool_c.global_params["x"]), rtol=0, atol=0)
    # the co-scripted events actually hit the run
    kinds = {e["kind"] for e in rep.applied_events}
    assert {"fabric", "slowdown"} <= kinds
    assert rep.sim_time > 0 and rep.comm_time > 0


# ------------------------------------------------- join re-pricing fix

def test_join_transfer_spanning_window_edge_is_repriced():
    """A flash_crowd_join parameter transfer in flight when a congestion
    window opens must be re-priced — fraction done credited, remainder
    re-costed.  The join record in ``applied_events`` keeps its
    launch-time price (records are immutable once appended); the
    re-pricing lands as an explicit ``xfer_reprice`` annotation whose
    ``xfer_s`` is the effective launch-to-arrival total."""
    join_t, window_t = 0.02, 0.025
    # duration <= 0: the window never closes, so the transfer crosses
    # exactly one edge and the expected value below has a closed form
    scen = (build_scenario("flash_crowd_join", start=join_t, joins=1)
            + [ClusterEvent(time=window_t, kind="fabric", bw_scale=1e-3,
                            extra_latency=0.05, duration=0.0)])
    acfg = dataclasses.replace(ACFG, num_outer_steps=12)
    # slow links: the 64 B payload takes ~0.01 s to ship, so the window
    # at join_t + 5 ms opens mid-transfer
    toy = dict(TOY, link_bw=6e3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(2)]
    from repro.cluster import NetworkModel, make_heterogeneous_profiles
    profiles = make_heterogeneous_profiles(8, **toy)
    _, _, rep = run_cluster(quad_loss, inits, streams, acfg,
                            policy="elastic", profiles=profiles,
                            network=NetworkModel(), scenario=scen,
                            fixed_batch=4)
    join = next(e for e in rep.applied_events if e["kind"] == "join")
    assert join["time"] == join_t

    net = NetworkModel()
    payload = 16 * 4                 # 16-dim float32 params
    old_single_price = net.point_to_point_time(payload, profiles[0],
                                               profiles[6], now=join_t)
    # the window opens while the transfer flies...
    assert join_t < window_t < join_t + old_single_price
    # ...and the correct re-priced duration credits the fraction done
    # then re-costs the remainder under the degraded fabric
    net.add_fabric_window(window_t, None, bw_scale=1e-3, extra_latency=0.05)
    frac_done = (window_t - join_t) / old_single_price
    new_total = net.point_to_point_time(payload, profiles[0], profiles[6],
                                        now=window_t)
    expected = (window_t - join_t) + (1.0 - frac_done) * new_total
    # the join record is a snapshot of the launch-time decision...
    assert join["xfer_s"] == pytest.approx(old_single_price, rel=1e-12)
    # ...and the re-price is its own annotation with the effective total
    rp = next(e for e in rep.applied_events
              if e["kind"] == "xfer_reprice")
    assert rp["time"] == window_t and rp["tid"] == join["tid"]
    assert rp["xfer_s"] == pytest.approx(expected, rel=1e-12)
    # the bug the re-pricing fixes: pricing once at launch undershoots
    assert rp["xfer_s"] > 3.0 * old_single_price
