"""Golden-trace regression tests for the scenario registry.

Every registered scenario, run with a fixed seed on a canonical 2-pod
cluster, must reproduce a stored digest of its ``ClusterReport`` —
summary *and* applied events — so a scheduler or cost-model refactor
cannot silently change simulated behavior.  The harness pins
``fixed_batch`` + ``adaptive=False`` so simulated timings are pure
Python float arithmetic (no jax numerics in the digest) and the goldens
hold across platforms.

If a change to the runtime/cost models is *intended* to move these
digests, rerun ``_run(name)`` for each scenario and update GOLDEN with
the new values — that diff is the reviewable record of the behavior
change.
"""
import dataclasses
import hashlib
import json

import pytest

from repro.configs.base import AdLoCoConfig
from repro.cluster import (Topology, interleave_pods, list_scenarios,
                           make_pod_profiles, run_cluster)
from repro.cluster.scenarios import build_scenario

from tests.test_adloco_integration import QuadStream, _quad_setup, quad_loss

TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)

# fixed_batch + adaptive=False: timings decouple from jax numerics, so
# the digests are pure-Python-float deterministic
ACFG = AdLoCoConfig(num_outer_steps=8, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=3, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32,
                    enable_merge=False, adaptive=False)

GOLDEN = {
    "baseline": "d84cea9f20b3edc8",
    "bursty_congestion": "d33d3451a9bcb212",
    "flash_crowd_join": "3260d6cef3af4529",
    "pod_partition": "868dc71fa3b7d1cc",
    "spot_churn": "4242497cbb02a519",
}


def _run(name):
    """Canonical scenario harness: 2 pods x 5 nodes at 2x pod speed
    ratio, interleaved so every trainer's M=2 workers span both pods
    (outer syncs always cross the bottleneck), 2 spare trainers' worth
    of nodes/streams for joiners."""
    profiles = make_pod_profiles([5, 5], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams = _quad_setup(k=3, M=2)
    streams = streams + [QuadStream(prob, 100 + i) for i in range(4)]
    return run_cluster(quad_loss, inits, streams, ACFG, policy="elastic",
                       profiles=interleaved, network=topo, scenario=name,
                       fixed_batch=4)


def _trace(rep):
    return {"summary": rep.summary(), "events": rep.applied_events}


def _digest(rep) -> str:
    blob = json.dumps(_trace(rep), sort_keys=True, default=float)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


_MEMO = {}


def _memo_run(name):
    if name not in _MEMO:
        _MEMO[name] = _run(name)
    return _MEMO[name]


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_scenario_matches_golden_trace(name):
    _, _, rep = _memo_run(name)
    assert _digest(rep) == GOLDEN[name], (
        f"scenario {name!r} produced a different event/timing trace: "
        f"{_trace(rep)}")


def test_every_registered_scenario_has_a_golden():
    """Registering a scenario without pinning its trace defeats the
    regression net — add a digest here when adding a generator."""
    assert sorted(list_scenarios()) == sorted(GOLDEN)


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_scenario_is_deterministic(name):
    """Same seed + scenario => identical ClusterReport, field by field
    (the acceptance criterion behind the golden digests)."""
    _, _, rep1 = _memo_run(name)
    _, _, rep2 = _run(name)
    assert rep1.summary() == rep2.summary()
    assert rep1.applied_events == rep2.applied_events


def test_scenarios_exercise_their_event_kinds():
    """The canonical harness must actually reach each scenario's events
    (a scenario whose events land after the run drains tests nothing)."""
    expected = {"bursty_congestion": {"fabric"},
                "pod_partition": {"fabric"},
                "flash_crowd_join": {"join"},
                "spot_churn": {"leave", "join"}}
    for name, kinds in expected.items():
        _, _, rep = _memo_run(name)
        assert kinds <= {e["kind"] for e in rep.applied_events}


def test_build_scenario_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown scenario"):
        build_scenario("nope")
    with pytest.raises(ValueError, match="unknown scenario"):
        _run("nope")


def test_spot_churn_seed_controls_stream():
    a = build_scenario("spot_churn", seed=0)
    b = build_scenario("spot_churn", seed=0)
    c = build_scenario("spot_churn", seed=7)
    assert [dataclasses.astuple(e) for e in a] == \
        [dataclasses.astuple(e) for e in b]
    assert [e.time for e in a] != [e.time for e in c]


def test_congestion_slows_sync_but_async_hides_it():
    """The fabric windows must actually bite: under the sync policy the
    congested run is strictly slower on the simulated clock than the
    baseline, and the async policy recovers most of the gap."""
    sims = {}
    for name in ("baseline", "bursty_congestion"):
        for policy in ("sync", "async"):
            profiles = make_pod_profiles([3, 3], ratio=1.0, **TOY)
            interleaved = interleave_pods(profiles)
            topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                          inter_latency=4e-3)
            _, inits, streams = _quad_setup(k=3, M=2)
            _, _, rep = run_cluster(quad_loss, inits, streams, ACFG,
                                    policy=policy, profiles=interleaved,
                                    network=topo, scenario=name,
                                    fixed_batch=4)
            sims[(name, policy)] = rep.sim_time
    assert sims[("bursty_congestion", "sync")] > \
        1.05 * sims[("baseline", "sync")]
    sync_overhead = (sims[("bursty_congestion", "sync")]
                     - sims[("baseline", "sync")])
    async_overhead = (sims[("bursty_congestion", "async")]
                      - sims[("baseline", "async")])
    assert async_overhead < sync_overhead
