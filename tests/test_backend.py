"""Execution-backend tests: the SimBackend refactor must be invisible
(bit-identical to the pre-backend runtime) and the JaxProcessBackend
must reproduce the simulator's numerics over *real* multi-process
``jax.distributed`` collectives — the sim/real parity contract CI's
``multiprocess-smoke`` lane enforces.

The multi-process tests spawn real OS processes (gloo CPU collectives)
via ``repro.cluster.launch_mp.run_mp`` — two for the single-trainer
parity runs, four for the k=2 multi-trainer merge run; everything else
runs in-process (a single-process JaxProcessBackend degenerates every
collective to the identity, which is exactly what makes it comparable
bit-for-bit).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco
from repro.cluster import (SimBackend, JaxProcessBackend, Topology,
                           interleave_pods, make_pod_profiles,
                           make_rack_profiles, run_cluster)
from repro.cluster import launch_mp
from repro.cluster.launch_mp import run_mp, run_sim

from tests.test_adloco_integration import QuadStream, _quad_setup, quad_loss

TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)

ACFG = AdLoCoConfig(num_outer_steps=8, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=3, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32,
                    enable_merge=False, adaptive=False)

#: parity tolerance for the real backend: the hierarchical pmean chain
#: may re-associate the mean, so "float tolerance", not bitwise — in
#: practice the 2-process runs come out bit-identical
PARITY_ATOL = 1e-6


def _pod_cluster():
    profiles = make_pod_profiles([5, 5], ratio=2.0, **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    return interleave_pods(profiles), topo


# ------------------------------------------------- SimBackend identity

def test_explicit_sim_backend_is_bit_identical_to_network_path():
    """run_cluster(backend=SimBackend(topo)) must reproduce
    run_cluster(network=topo) exactly — same params, same report — on
    an elastic scenario run that exercises joins, leaves, fabric
    windows and in-flight re-pricing."""
    def go(use_backend):
        interleaved, topo = _pod_cluster()
        prob, inits, streams = _quad_setup(k=3, M=2)
        streams = streams + [QuadStream(prob, 100 + i) for i in range(4)]
        kw = ({"backend": SimBackend(topo)} if use_backend
              else {"network": topo})
        return run_cluster(quad_loss, inits, streams, ACFG,
                           policy="elastic", profiles=interleaved,
                           scenario="spot_churn", fixed_batch=4, **kw)

    pool_a, _, rep_a = go(False)
    pool_b, _, rep_b = go(True)
    assert rep_a.summary() == rep_b.summary()
    assert rep_a.applied_events == rep_b.applied_events
    np.testing.assert_allclose(
        np.asarray(pool_a.global_params["x"]),
        np.asarray(pool_b.global_params["x"]), rtol=0, atol=0)
    # the sim backend never claims measured wire time
    assert rep_b.real_comm_time == 0.0


def test_backend_and_network_are_mutually_exclusive():
    _, inits, streams = _quad_setup()
    with pytest.raises(ValueError, match="not both"):
        run_cluster(quad_loss, inits, streams, ACFG,
                    network=Topology(pods=[["a"], ["b"]], inter_bw=1e5),
                    backend=SimBackend())


def test_sim_backend_rejects_partial_worker_sets():
    with pytest.raises(ValueError, match="partial worker set"):
        SimBackend().outer_reduce([{"x": np.ones(2)}, None])


# ------------------------------------------- participant-tree mapping

def test_participant_tree_prunes_and_collapses():
    profiles = make_rack_profiles([[2, 2], [2, 2]], **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5, pod_bw=1.5e5)
    names = [p.name for p in profiles]
    # full cluster: 2 pods x 2 racks x 2 nodes, fully nested
    assert topo.participant_tree(names) == [
        [["p0r0n0", "p0r0n1"], ["p0r1n0", "p0r1n1"]],
        [["p1r0n0", "p1r0n1"], ["p1r1n0", "p1r1n1"]]]
    # one rack: single-child levels collapse to a flat leaf group
    assert topo.participant_tree(["p0r0n0", "p0r0n1"]) == \
        ["p0r0n0", "p0r0n1"]
    # one node per pod: each pod collapses to its single participating
    # rack's leaf group; the cross-pod level survives
    assert topo.participant_tree(["p0r0n0", "p1r0n1"]) == \
        [["p0r0n0"], ["p1r0n1"]]
    # caller order is preserved inside leaf groups (worker <-> process
    # identification depends on it)
    assert topo.participant_tree(["p0r0n1", "p0r0n0"]) == \
        ["p0r0n1", "p0r0n0"]


# -------------------------------------- JaxProcessBackend, in-process

def test_jax_backend_single_process_matches_sim_bitwise():
    """With one process the real backend's collectives degenerate to
    the identity: the run must match the SimBackend bit-for-bit while
    still exercising the full mesh/shard_map execution path."""
    acfg, inits, streams, profiles, network = launch_mp.fixture(
        1, rounds=3)
    pool, hist, rep = run_cluster(
        launch_mp.quad_loss, inits, streams, acfg, policy="sync",
        profiles=profiles, backend=JaxProcessBackend(network),
        fixed_batch=4)
    ref = run_sim(1, rounds=3)
    np.testing.assert_allclose(
        np.asarray(pool.global_params["x"], np.float64),
        np.asarray(ref["x"]), rtol=0, atol=0)
    assert rep.sim_time == ref["sim_time"]
    assert rep.num_syncs == ref["num_syncs"]
    # measured wire time is recorded per event and in aggregate
    assert rep.real_comm_time > 0.0
    outer = [e for e in pool.comms.log if e["kind"] == "outer"]
    assert outer and all("real_s" in e for e in outer)
    assert pool.comms.total_real_time == pytest.approx(rep.real_comm_time)


def test_jax_backend_validates_unsupported_configs():
    from repro.cluster.runtime import ClusterEvent

    from repro.cluster import make_heterogeneous_profiles

    acfg, inits, streams, profiles, network = launch_mp.fixture(
        1, rounds=2)
    many = make_heterogeneous_profiles(4, **TOY)

    def go(acfg=acfg, inits=inits, streams=streams, profiles=profiles,
           **kw):
        return run_cluster(launch_mp.quad_loss, inits, streams, acfg,
                           profiles=profiles,
                           backend=JaxProcessBackend(network),
                           fixed_batch=4, **kw)

    with pytest.raises(ValueError, match="sync/async"):
        go(policy="elastic")
    with pytest.raises(ValueError, match="one worker per process"):
        go(acfg=dataclasses.replace(acfg, nodes_per_gpu=2),
           streams=streams * 2, profiles=many)
    with pytest.raises(ValueError, match="k=2"):
        go(inits=inits * 2, streams=streams * 2, profiles=many,
           acfg=dataclasses.replace(acfg, num_init_trainers=2))
    with pytest.raises(ValueError, match="elastic in-process pool"):
        go(scenario=[ClusterEvent(time=0.0, kind="join")])

    # multi-trainer pools and merges are supported now: k=2 with
    # enable_merge validates whenever the process count matches the
    # k x M group layout...
    backend = JaxProcessBackend(network)
    backend.num_processes = 2
    merged = dataclasses.replace(acfg, enable_merge=True,
                                 num_init_trainers=2)
    backend.validate(merged, policy="sync", k=2, M=1)
    # ...but adaptive batching still reduces stats over the whole mesh,
    # so it stays k=1-only
    with pytest.raises(ValueError, match="trainer group"):
        backend.validate(dataclasses.replace(merged, adaptive=True),
                         policy="sync", k=2, M=1)


def test_jax_backend_adaptive_validation():
    """Adaptive batching is supported now — but only through the
    composable microbatch estimator when the statistics actually span
    processes (a rank-local per-sample probe would desynchronize the
    batch decision)."""
    acfg, _, _, _, network = launch_mp.fixture(1, rounds=2)
    backend = JaxProcessBackend(network)

    # multi-process + per-sample probe: rejected with a pointed message
    backend.num_processes = 2
    bad = dataclasses.replace(acfg, adaptive=True,
                              stats_estimator="per_sample")
    with pytest.raises(ValueError, match="microbatch"):
        backend.validate(bad, policy="sync", k=1, M=2)
    # multi-process + microbatch estimator: accepted
    ok = dataclasses.replace(acfg, adaptive=True,
                             stats_estimator="microbatch")
    backend.validate(ok, policy="sync", k=1, M=2)
    # single process: every worker is local, both estimators fine
    backend.num_processes = 1
    backend.validate(bad, policy="sync", k=1, M=1)


def test_jax_backend_rejects_autoscale():
    """An ElasticPolicy scripts joins/leaves through the in-process
    elastic pool; a fixed process set cannot honor it.  run_cluster
    already refuses autoscale on the sync/async policies, so the
    backend contract is pinned on validate() directly."""
    from repro.cluster.autoscale import BandAutoscale

    acfg, _, _, _, network = launch_mp.fixture(1, rounds=2)
    backend = JaxProcessBackend(network)
    with pytest.raises(ValueError, match="cannot grow or shrink"):
        backend.validate(acfg, policy="sync", k=1, M=1,
                         autoscale=BandAutoscale())
    backend.validate(acfg, policy="sync", k=1, M=1)  # None: accepted


def test_jax_backend_single_process_predicted_matches_sim_bitwise():
    """k_correct > 1 through the JaxProcessBackend on one process must
    reproduce the SimBackend trajectory bit-for-bit: the predictor is
    pure local float arithmetic, so prediction cannot introduce a
    backend-dependent decision."""
    acfg, inits, streams, profiles, network = launch_mp.fixture(
        1, rounds=6, adaptive=True, k_correct=3)
    pool, hist, rep = run_cluster(
        launch_mp.quad_loss, inits, streams, acfg, policy="sync",
        profiles=profiles, backend=JaxProcessBackend(network))
    ref = run_sim(1, rounds=6, adaptive=True, k_correct=3)
    np.testing.assert_allclose(
        np.asarray(pool.global_params["x"], np.float64),
        np.asarray(ref["x"]), rtol=0, atol=0)
    assert hist.requested_batches == ref["batches"]
    assert hist.modes == ref["modes"]
    # corrections at rounds 1 and 4; the other four rounds predicted
    assert rep.num_stats_syncs == ref["num_stats_syncs"] == 2
    assert rep.num_predicted_rounds == 4


def test_jax_backend_single_process_adaptive_matches_sim_bitwise():
    """Adaptive + switch through the JaxProcessBackend on one process
    must reproduce the SimBackend bit-for-bit: the stats reducer is
    None (all workers local), so the in-process estimator path — and
    therefore the whole batch/plan trajectory — is shared."""
    acfg, inits, streams, profiles, network = launch_mp.fixture(
        1, rounds=4, adaptive=True)
    pool, hist, rep = run_cluster(
        launch_mp.quad_loss, inits, streams, acfg, policy="sync",
        profiles=profiles, backend=JaxProcessBackend(network))
    ref = run_sim(1, rounds=4, adaptive=True)
    np.testing.assert_allclose(
        np.asarray(pool.global_params["x"], np.float64),
        np.asarray(ref["x"]), rtol=0, atol=0)
    assert rep.sim_time == ref["sim_time"]
    assert hist.requested_batches == ref["batches"]
    assert hist.modes == ref["modes"]
    # every adaptive round priced a stats reduction
    assert rep.num_stats_syncs == ref["num_stats_syncs"] > 0


# ------------------------------------- real 2-process differential run

@pytest.mark.mp
def test_two_process_sync_run_matches_sim_and_host_loop():
    """The headline differential guarantee: a 2-process
    JaxProcessBackend sync run — real ``jax.distributed`` collectives —
    must land on the same final parameters as the SimBackend event loop
    AND the legacy ``train_adloco`` host loop, to float tolerance."""
    res = run_mp(2, rounds=6, policy="sync")
    assert res["num_syncs"] == 6 and res["real_comm_time"] > 0.0

    ref = run_sim(2, rounds=6, policy="sync")
    np.testing.assert_allclose(np.asarray(res["x"]), np.asarray(ref["x"]),
                               rtol=0, atol=PARITY_ATOL)
    assert res["sim_time"] == ref["sim_time"]

    acfg, inits, streams, _, _ = launch_mp.fixture(2, rounds=6)
    pool, _ = train_adloco(launch_mp.quad_loss, inits, streams, acfg,
                           fixed_batch=4)
    np.testing.assert_allclose(
        np.asarray(res["x"]),
        np.asarray(pool.global_params["x"], np.float64),
        rtol=0, atol=PARITY_ATOL)


@pytest.mark.mp
def test_two_process_async_run_matches_sim():
    """The async policy's delayed-apply/rebase schedule must survive
    real collectives unchanged: same event order, same numerics."""
    res = run_mp(2, rounds=5, policy="async")
    ref = run_sim(2, rounds=5, policy="async")
    np.testing.assert_allclose(np.asarray(res["x"]), np.asarray(ref["x"]),
                               rtol=0, atol=PARITY_ATOL)
    assert res["sim_time"] == ref["sim_time"]
    assert res["num_syncs"] == ref["num_syncs"]


@pytest.mark.mp
def test_two_process_hierarchical_groups_match_sim():
    """2-pod Topology: the FabricDomain tree maps onto nested mesh axes
    (one process per pod) and the grouped-collective reduction must
    still agree with the simulator."""
    res = run_mp(2, rounds=4, policy="sync", pods=True)
    ref = run_sim(2, rounds=4, policy="sync", pods=True)
    np.testing.assert_allclose(np.asarray(res["x"]), np.asarray(ref["x"]),
                               rtol=0, atol=PARITY_ATOL)
    assert res["sim_time"] == ref["sim_time"]


@pytest.mark.mp
def test_two_process_adaptive_switch_run_agrees():
    """The distributed adaptive headline: a 2-process adaptive + switch
    run — batch stats composed by a real ``lax.pmean`` all-reduce each
    round — must (a) keep every rank on the identical ExecutionPlan
    sequence (the worker asserts cross-rank agreement via allgather and
    exits nonzero on divergence), and (b) land on the SimBackend's
    batch/plan trajectory and final params to the pinned tolerance."""
    res = run_mp(2, rounds=6, policy="sync", adaptive=True)
    ref = run_sim(2, rounds=6, policy="sync", adaptive=True)
    # trajectory identity: same requested batches, same modes -> same
    # plan_execution outputs (a pure function of batch and config)
    assert res["batches"] == ref["batches"]
    assert res["modes"] == ref["modes"]
    assert res["num_stats_syncs"] == ref["num_stats_syncs"] > 0
    # the ramp is real: batches grew and switch mode engaged
    firsts = [b[0] for b in res["batches"]]
    assert firsts[-1] > firsts[0]
    assert any(m == "accum" for ms in res["modes"] for m in ms)
    np.testing.assert_allclose(np.asarray(res["x"]), np.asarray(ref["x"]),
                               rtol=0, atol=PARITY_ATOL)
    # identical batch ints feed identical pure-float pricing
    assert res["sim_time"] == ref["sim_time"]
    assert res["real_comm_time"] > 0.0


@pytest.mark.mp
def test_four_process_two_trainer_merge_matches_sim():
    """The multi-trainer tentpole: 4 processes as k=2 disjoint trainer
    groups — each outer sync a grouped mean over its own group's mesh
    axes, and the MIT merge a *global* weighted psum across groups —
    must land on the SimBackend's params, merge trajectory, and sim
    clock.  At least one merge must actually execute, or the
    cross-group collective path wasn't exercised."""
    res = run_mp(4, rounds=6, policy="sync", k=2, merge=True)
    ref = run_sim(4, rounds=6, policy="sync", k=2, merge=True)
    assert res["merge_events"] == ref["merge_events"]
    assert any(e["kind"] == "merge" for e in res["merge_events"])
    np.testing.assert_allclose(np.asarray(res["x"]), np.asarray(ref["x"]),
                               rtol=0, atol=PARITY_ATOL)
    assert res["sim_time"] == ref["sim_time"]
    assert res["num_syncs"] == ref["num_syncs"]
    assert res["real_comm_time"] > 0.0


@pytest.mark.mp
def test_two_process_trace_digest_matches_sim(tmp_path):
    """The trace layer's lockstep contract: the sim-span trace recorded
    inside a real 2-process run must be digest-identical to the
    SimBackend reference (both backends drive the same deterministic
    event loop with analytic span payloads), while the real backend
    additionally lays measured wall-clock spans on the second clock —
    one per executed collective, each with nonzero duration."""
    from repro.cluster import Trace, validate_perfetto
    out = tmp_path / "mp.perfetto.json"
    res = run_mp(2, rounds=4, policy="async", adaptive=True,
                 trace=str(out))
    ref = run_sim(2, rounds=4, policy="async", adaptive=True, trace=True)
    assert res["trace_digest"] == ref["trace_digest"]
    assert res["overlap_frac"] == ref["overlap_frac"] > 0.0
    assert res["utilization"] == ref["utilization"]
    assert res["real_span_time"] > 0.0
    # the nonblocking contract, on the wall clock: dispatched collective
    # windows (dispatch -> ready) must coincide with measured inner
    # compute — async dispatch is real, not a simulated claim
    assert res["real_overlap_frac"] > 0.0
    # the exported Perfetto file carries both clocks and validates
    data = json.loads(out.read_text())
    assert validate_perfetto(data) == []
    tr = Trace.from_perfetto(data)
    assert tr.sim_digest() == ref["trace_digest"]
    reals = tr.real_spans()
    assert len(reals) == res["num_real_spans"]
    # real-span census: one in-flight window per dispatched outer
    # collective ("piggyback" when the phase-1 stats vector rode along,
    # "outer" otherwise), plus the noted inner-compute windows.  The
    # phase-2 moment reduction is chained onto the piggyback window at
    # fold time, so no standalone "stats" span remains.
    kinds = {}
    for s in reals:
        kinds[s.kind] = kinds.get(s.kind, 0) + 1
    assert (kinds.get("outer", 0) + kinds.get("piggyback", 0)
            == res["num_syncs"])
    assert kinds.get("piggyback", 0) == res["num_stats_syncs"] > 0
    assert kinds.get("stats", 0) == 0
    assert kinds.get("compute", 0) > 0
    assert all(s.duration > 0.0 for s in reals)
