"""Quickstart: AdLoCo in ~60 lines.

Trains a reduced MicroLlama (the paper's model family) with the full
three-stage method — adaptive batching (norm test), multi-instance
training with merging, and SwitchMode gradient accumulation — on the
synthetic C4-stand-in stream, then prints the convergence / communication
history.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import models
from repro.configs import get_config, reduced
from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco
from repro.data import make_shard_streams


def main():
    # 1. model: any --arch id works; 'reduced' makes it CPU-friendly
    cfg = reduced(get_config("microllama-300m"))
    print(f"model: {cfg.name}  ({cfg.param_count() / 1e6:.1f}M params)")

    # 2. AdLoCo hyperparameters (paper Table 1, scaled down for a demo)
    acfg = AdLoCoConfig(
        num_outer_steps=6,        # T
        num_inner_steps=4,        # H
        num_init_trainers=3,      # k trainer instances (MIT)
        nodes_per_gpu=2,          # M workers per trainer
        initial_batch_size=2,
        max_batch=8,              # per-device memory cap b_max
        switch_multiplier=2,      # accumulate once b_req > 2*b_max
        merge_frequency=3,        # CheckMerge cadence
        eta=0.8,                  # norm-test threshold
        lr_inner=3e-4, lr_outer=0.5,
        stats_probe_size=16,
    )

    # 3. k*M data shards (the paper's D_i) + k independent inits
    k, M = acfg.num_init_trainers, acfg.nodes_per_gpu
    keys = jax.random.split(jax.random.PRNGKey(0), k)
    init_params = [models.init_params(cfg, kk) for kk in keys]
    streams = make_shard_streams(cfg.vocab_size, seq_len=32,
                                 num_shards=k * M, seed=0)
    loss_fn = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731

    # 4. run Algorithm 3
    pool, hist = train_adloco(loss_fn, init_params, streams, acfg,
                              verbose=True)

    print("\nouter  loss    pool  requested_batches  comm_events  mode")
    for i, t in enumerate(hist.outer_step):
        print(f"{t:4d}  {hist.loss[i]:7.4f}  {hist.pool_size[i]:3d}  "
              f"{str(hist.requested_batches[i]):18s} "
              f"{hist.comm_events[i]:6d}      {hist.modes[i]}")
    print(f"\nfinal pool size: {pool.k} "
          f"(started with {acfg.num_init_trainers})")
    print(f"communication:   {pool.comms.events} events, "
          f"{pool.comms.total_bytes / 2**20:.1f} MiB (ring model)")


if __name__ == "__main__":
    main()
