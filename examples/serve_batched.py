"""Serving example: batched prefill + autoregressive decode with a KV
cache, across three architecture families (dense GQA, SSM, hybrid).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models, serve
from repro.configs import get_config, reduced


def demo(arch: str, n_requests: int = 4, prompt_len: int = 12,
         new_tokens: int = 16):
    cfg = reduced(get_config(arch))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (n_requests, prompt_len)), jnp.int32)

    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jnp.asarray(
            rng.standard_normal((n_requests, cfg.num_prefix_tokens,
                                 cfg.d_model)), jnp.float32)
    elif cfg.frontend is not None:
        kw["prefix_emb"] = jnp.asarray(
            rng.standard_normal((n_requests, cfg.num_prefix_tokens,
                                 cfg.d_model)), jnp.float32)

    t0 = time.time()
    res = serve.generate(params, cfg, prompts, max_new_tokens=new_tokens,
                         temperature=0.0,
                         cache_len=prompt_len + new_tokens + 4, **kw)
    wall = time.time() - t0
    tput = n_requests * new_tokens / wall
    print(f"{arch:22s} [{cfg.arch_type:6s}] {n_requests} reqs x "
          f"{new_tokens} tokens in {wall:5.1f}s  ({tput_fmt(tput)})  "
          f"first request: {res.tokens[0][:8]}...")


def tput_fmt(tps: float) -> str:
    return f"{tps:6.1f} tok/s"


def main():
    print("batched greedy decoding, reduced configs, CPU:")
    for arch in ("qwen3-0.6b",          # dense GQA + qk-norm
                 "falcon-mamba-7b",     # attention-free SSM (O(1) state)
                 "hymba-1.5b",          # hybrid attn+SSM heads
                 "gemma3-4b",           # sliding-window dense
                 "whisper-small"):      # enc-dec with audio-frame stub
        demo(arch)


if __name__ == "__main__":
    main()
