"""End-to-end driver: pre-train a ~100M-parameter MicroLlama-family model
with AdLoCo for a few hundred inner steps, with checkpointing and a JSON
history dump — the paper's experiment (§6.1) at container scale.

  PYTHONPATH=src python examples/train_100m.py                # full run
  PYTHONPATH=src python examples/train_100m.py --demo         # 2-minute demo

The full run performs T=10 outer rounds x H=8 inner steps x M=2 workers
x k=2..1 trainers ~= 300+ optimizer steps on a 97M model, on whatever
devices JAX sees (CPU here, a TPU slice in deployment).
"""
import argparse
import json
import os
import time

import jax

from repro import models
from repro.checkpoint import save_train_state
from repro.configs import get_config
from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco
from repro.data import make_shard_streams

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "train_100m")


def build_config(demo: bool):
    """~97M params: MicroLlama geometry, 6 layers of d=768."""
    cfg = get_config("microllama-300m").with_overrides(
        name="microllama-97m", num_layers=6, d_model=768, num_heads=12,
        num_kv_heads=4, d_ff=2048, dtype="float32")
    if demo:
        cfg = cfg.with_overrides(num_layers=2, d_model=256, num_heads=4,
                                 d_ff=512, vocab_size=2048,
                                 name="microllama-demo")
    return cfg


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--demo", action="store_true",
                    help="tiny model / 2-minute run")
    ap.add_argument("--outer-steps", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    args = ap.parse_args()

    cfg = build_config(args.demo)
    T = args.outer_steps or (4 if args.demo else 10)
    seq = args.seq_len or (32 if args.demo else 128)
    acfg = AdLoCoConfig(
        num_outer_steps=T, num_inner_steps=8, lr_inner=3e-4, lr_outer=0.5,
        num_init_trainers=2, nodes_per_gpu=2, initial_batch_size=2,
        merge_frequency=4, eta=0.8, max_batch=8, switch_multiplier=2,
        stats_probe_size=8, weight_decay=0.1)

    n = cfg.param_count()
    steps = T * acfg.num_inner_steps * acfg.nodes_per_gpu \
        * acfg.num_init_trainers
    print(f"[100m] {cfg.name}: {n / 1e6:.1f}M params, "
          f"up to {steps} inner optimizer steps "
          f"(T={T} x H={acfg.num_inner_steps} x M={acfg.nodes_per_gpu} "
          f"x k<={acfg.num_init_trainers}), seq_len={seq}")

    k, M = acfg.num_init_trainers, acfg.nodes_per_gpu
    keys = jax.random.split(jax.random.PRNGKey(0), k)
    init_params = [models.init_params(cfg, kk) for kk in keys]
    streams = make_shard_streams(cfg.vocab_size, seq, k * M, seed=0)
    loss_fn = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731

    # held-out eval shard
    held = make_shard_streams(cfg.vocab_size, seq, 1, seed=77)[0]
    eval_batch = held.next_batch(8)
    eval_jit = jax.jit(lambda p: loss_fn(p, eval_batch)[0])
    eval_fn = lambda p: float(eval_jit(p))  # noqa: E731

    t0 = time.time()
    pool, hist = train_adloco(loss_fn, init_params, streams, acfg,
                              eval_fn=eval_fn, verbose=True)
    wall = time.time() - t0

    os.makedirs(OUT, exist_ok=True)
    save_train_state(OUT, T, pool)
    with open(os.path.join(OUT, "history.json"), "w") as f:
        json.dump(hist.as_dict(), f, indent=2)
    print(f"\n[100m] done in {wall:.0f}s: "
          f"train {hist.loss[0]:.3f} -> {hist.loss[-1]:.3f}, "
          f"eval {hist.eval_loss[0]:.3f} -> {hist.eval_loss[-1]:.3f}")
    print(f"[100m] comm: {pool.comms.events} events "
          f"{pool.comms.total_bytes / 2**30:.2f} GiB; "
          f"final pool k={pool.k}; "
          f"batches {hist.requested_batches[0]} -> "
          f"{hist.requested_batches[-1]}")
    print(f"[100m] checkpoint + history -> {os.path.abspath(OUT)}")


if __name__ == "__main__":
    main()
