"""Tour of the virtual-cluster runtime: AdLoCo on simulated
heterogeneous hardware with stragglers, a trainer leaving, a fresh one
joining, a 2-pod topology whose cross-pod bottleneck gets congested,
and a 3-level rack/pod/cluster fabric where a whole pod fails at once —
comparing sync vs async outer-sync policies on the simulated clock,
then tracing a run to see *where* the time goes (per-trainer
busy/blocked/idle ledger, overlap fraction, Perfetto export).

  PYTHONPATH=src python examples/heterogeneous_cluster.py
  # then load the written trace.json in https://ui.perfetto.dev
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import AdLoCoConfig
from repro.cluster import (ClusterEvent, Topology, Trace, interleave_pods,
                           make_heterogeneous_profiles, make_pod_profiles,
                           make_rack_profiles, run_cluster)

from benchmarks.common import QuadStream, quad_setup, quad_loss  # noqa: E402

# toy-scale hardware so the 16-dim proxy's compute and its 64-byte
# all-reduces both land in the millisecond range (see cluster_bench)
TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)

ACFG = AdLoCoConfig(
    num_outer_steps=16, num_inner_steps=5, lr_inner=0.05, lr_outer=0.7,
    outer_momentum=0.5, num_init_trainers=3, nodes_per_gpu=2,
    initial_batch_size=2, merge_frequency=3, eta=0.8, max_batch=16,
    inner_optimizer="sgd", stats_probe_size=32, enable_merge=False)


def timeline(hist, width: int = 56):
    """eval loss vs simulated time, one row per sync arrival (thinned)."""
    if not hist.eval_loss:
        return
    lo = min(hist.eval_loss)
    hi = max(hist.eval_loss)
    step = max(len(hist.eval_loss) // 12, 1)
    for i in range(0, len(hist.eval_loss), step):
        v, s = hist.eval_loss[i], hist.sim_time[i]
        bar = int((v - lo) / max(hi - lo, 1e-9) * (width - 1))
        print(f"    {s * 1e3:9.2f}ms |{'#' * (bar + 1):<{width}}| "
              f"E[f]={v:.3f}")


def main():
    print("=== 1. heterogeneous nodes: 6 nodes, fastest 4x the slowest")
    profiles = make_heterogeneous_profiles(6, ratio=4.0, jitter=0.1, **TOY)
    for p in profiles:
        print(f"    {p.name}: {p.flops / 1e6:.2f} MFLOP/s, "
              f"link {p.link_bw / 1e3:.0f} KB/s")

    results = {}
    for policy in ("sync", "async"):
        prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=0)
        pool, hist, rep = run_cluster(
            quad_loss, inits, streams, ACFG, policy=policy,
            profiles=profiles, eval_fn=eval_fn)
        results[policy] = (hist, rep, eval_fn(pool.global_params))

    print("\n=== 2. sync policy (barrier on every outer all-reduce)")
    hist, rep, final = results["sync"]
    timeline(hist)
    print(f"    total {rep.sim_time * 1e3:.1f}ms simulated "
          f"({rep.comm_time * 1e3:.1f}ms in collectives), "
          f"final E[f]={final:.4f}")

    print("\n=== 3. async policy (ACCO-style: accumulate while the "
          "all-reduce flies)")
    hist, rep, final = results["async"]
    timeline(hist)
    print(f"    total {rep.sim_time * 1e3:.1f}ms simulated "
          f"({rep.comm_time * 1e3:.1f}ms in collectives, hidden behind "
          f"compute), final E[f]={final:.4f}")
    sync_t = results["sync"][1].sim_time
    print(f"    speedup over sync: {sync_t / rep.sim_time:.2f}x at equal "
          f"outer steps")

    print("\n=== 4. elastic: straggler burst, one trainer leaves, a "
          "fresh one joins")
    prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=0)
    streams += [QuadStream(prob, 100 + i) for i in range(2)]  # spare shards
    profiles8 = make_heterogeneous_profiles(8, ratio=2.0, **TOY)
    scen = [ClusterEvent(time=0.01, kind="slowdown", node=5, factor=4.0,
                         duration=0.2),
            ClusterEvent(time=0.05, kind="leave"),
            ClusterEvent(time=0.15, kind="join")]
    acfg = dataclasses.replace(ACFG, enable_merge=True)
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy="elastic",
        profiles=profiles8, eval_fn=eval_fn, scenario=scen)
    for e in rep.applied_events:
        print(f"    t={e['time'] * 1e3:8.2f}ms  {e['kind']:9s} "
              f"{ {k: v for k, v in e.items() if k not in ('time', 'kind')} }")
    print(f"    final pool k={pool.k}, E[f]={eval_fn(pool.global_params):.4f} "
          f"after {rep.sim_time * 1e3:.1f}ms simulated")

    print("\n=== 5. topology: 2 pods, every trainer spanning the "
          "cross-pod bottleneck,\n       with bursty congestion windows "
          "on the inter-pod links")
    profiles = make_pod_profiles([3, 3], ratio=2.0, **TOY)
    # interleave so each trainer's M=2 workers sit in different pods:
    # every outer all-reduce is a per-pod reduce + cross-pod exchange
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    for pi, pod in enumerate(topo.pods):
        print(f"    pod{pi}: {', '.join(pod)}")
    for policy in ("sync", "async"):
        prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=0)
        pool, hist, rep = run_cluster(
            quad_loss, inits, streams, ACFG, policy=policy,
            profiles=interleaved, network=topo, eval_fn=eval_fn,
            scenario="bursty_congestion")   # registered scenario, by name
        n_win = sum(1 for e in rep.applied_events if e["kind"] == "fabric")
        print(f"    {policy:5s}: {rep.sim_time * 1e3:6.1f}ms simulated "
              f"({rep.comm_time * 1e3:6.1f}ms in collectives, {n_win} "
              f"congestion windows re-priced in flight), "
              f"E[f]={eval_fn(pool.global_params):.4f}")

    print("\n=== 6. three levels: 2 pods x 2 racks x 2 nodes, and a "
          "correlated pod\n       failure (the pod's nodes slow down AND "
          "the pod uplinks degrade together)")
    profiles = make_rack_profiles([[2, 2], [2, 2]], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    print(f"    domains: {', '.join(topo.domain_names())}")
    for policy in ("sync", "async"):
        prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=0)
        pool, hist, rep = run_cluster(
            quad_loss, inits, streams, ACFG, policy=policy,
            profiles=interleaved, network=topo, eval_fn=eval_fn,
            scenario="correlated_pod_failure")
        kinds = [e["kind"] for e in rep.applied_events]
        print(f"    {policy:5s}: {rep.sim_time * 1e3:6.1f}ms simulated "
              f"({rep.comm_time * 1e3:6.1f}ms in collectives), "
              f"events={'+'.join(kinds)}, "
              f"E[f]={eval_fn(pool.global_params):.4f}")

    print("\n=== 7. tracing: where does the async run's time actually "
          "go?")
    # re-run the 2-pod congested sweep with a trace attached: the event
    # loop records one span per compute block / collective / stats
    # reduction, and the ledger partitions every trainer's lifetime
    profiles = make_pod_profiles([3, 3], ratio=2.0, **TOY)
    interleaved = interleave_pods(profiles)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=0)
    tr = Trace()
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, ACFG, policy="async",
        profiles=interleaved, network=topo, eval_fn=eval_fn,
        scenario="bursty_congestion", trace=tr)
    print("    tid   alive      busy         blocked      idle")
    for tid, led in tr.utilization().items():
        print(f"    {tid:3d} {led['alive'] * 1e3:6.1f}ms "
              + " ".join(f"{led[k] * 1e3:6.1f}ms ({led[k] / led['alive']:4.0%})"
                         for k in ("busy", "blocked", "idle")))
    summ = tr.utilization_summary()
    print(f"    fleet utilization={summ['utilization']:.3f} "
          f"(blocked={summ['blocked_frac']:.3f}, "
          f"idle={summ['idle_frac']:.3f})")
    print(f"    overlap fraction={tr.overlap_fraction():.3f} — the share "
          f"of collective\n    in-flight time hidden behind compute "
          f"(sync would score exactly 0)")
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "trace.json")
    import json
    with open(out, "w") as f:
        json.dump(tr.to_perfetto(), f)
    print(f"    wrote {out} — load it in https://ui.perfetto.dev, or:\n"
          f"      PYTHONPATH=src python -m repro.cluster.trace_report "
          f"{os.path.relpath(out)}")


if __name__ == "__main__":
    main()
