"""Continuous batching: requests of different lengths join and leave the
decode batch mid-flight — no slot idles waiting for a straggler.

Part 1 drives a mixed bag of requests through the paged batcher by
hand; part 2 replays a flash-crowd arrival trace and prints the
scheduler report (tokens/tick, latency percentiles, peak concurrency).

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, reduced
from repro.serve import traffic
from repro.serve.scheduler import ContinuousBatcher, Request


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # 8 requests, wildly different prompt/generation lengths, 3 slots
    reqs = [Request(rid=i,
                    tokens=[int(t) for t in
                            rng.integers(0, cfg.vocab_size,
                                         (int(rng.integers(3, 12)),))],
                    max_new_tokens=int(rng.integers(3, 14)))
            for i in range(8)]
    total_new = sum(r.max_new_tokens for r in reqs)

    cb = ContinuousBatcher(params, cfg, n_slots=3, cache_len=32)
    for r in reqs:
        cb.submit(r)
    t0 = time.time()
    done = cb.run()
    wall = time.time() - t0

    print(f"{len(done)} requests, {total_new} total new tokens, "
          f"{cb.steps} batched decode steps (vs {total_new} sequential), "
          f"{wall:.1f}s")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req {rid}: prompt {len(r.tokens):2d} toks -> "
              f"{r.generated}")

    # part 2: a flash crowd lands on the paged batcher — short requests
    # hold only the blocks they touch, so concurrency can ride above
    # what a dense cache of equal memory would ever admit
    arr = traffic.make_arrivals("flash_crowd", n_requests=12, seed=3)
    cb = ContinuousBatcher(params, cfg, n_slots=6, cache_len=32,
                           block_size=8, num_blocks=12, chunk_size=4)
    rep = cb.run_trace(traffic.materialize(arr, cfg.vocab_size, seed=3))
    print(f"\nflash_crowd x12 on 12 shared blocks: "
          f"{rep.tokens} tokens in {rep.ticks} ticks "
          f"({rep.tokens_per_tick:.2f} tok/tick), "
          f"p50 latency {rep.p50_latency:.0f} ticks, "
          f"peak concurrency {rep.max_concurrency}, "
          f"peak blocks {rep.peak_blocks}, "
          f"preemptions {rep.preemptions}")


if __name__ == "__main__":
    main()
