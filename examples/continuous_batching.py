"""Continuous batching: requests of different lengths join and leave the
decode batch mid-flight — no slot idles waiting for a straggler.

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_config, reduced
from repro.serve.scheduler import ContinuousBatcher, Request


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # 8 requests, wildly different prompt/generation lengths, 3 slots
    reqs = [Request(rid=i,
                    tokens=[int(t) for t in
                            rng.integers(0, cfg.vocab_size,
                                         (int(rng.integers(3, 12)),))],
                    max_new_tokens=int(rng.integers(3, 14)))
            for i in range(8)]
    total_new = sum(r.max_new_tokens for r in reqs)

    cb = ContinuousBatcher(params, cfg, n_slots=3, cache_len=32)
    for r in reqs:
        cb.submit(r)
    t0 = time.time()
    done = cb.run()
    wall = time.time() - t0

    print(f"{len(done)} requests, {total_new} total new tokens, "
          f"{cb.steps} batched decode steps (vs {total_new} sequential), "
          f"{wall:.1f}s")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req {rid}: prompt {len(r.tokens):2d} toks -> "
              f"{r.generated}")


if __name__ == "__main__":
    main()
