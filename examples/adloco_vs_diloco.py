"""Paper Figure 1 at demo scale: AdLoCo vs vanilla DiLoCo convergence
and communication on the same shards, with an ASCII plot.

  PYTHONPATH=src python examples/adloco_vs_diloco.py
"""
import dataclasses

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco, train_diloco

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import lm_setup, quad_setup, quad_loss  # noqa: E402


def ascii_plot(series: dict, width: int = 60, height: int = 14):
    """series: {label: [(x, y), ...]} — x = comm events, y = eval loss."""
    all_pts = [p for pts in series.values() for p in pts]
    xs = [p[0] for p in all_pts]
    ys = [p[1] for p in all_pts]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    grid = [[" "] * width for _ in range(height)]
    marks = "ox+*"
    for (label, pts), mark in zip(series.items(), marks):
        for x, y in pts:
            i = int((1 - (y - y0) / max(y1 - y0, 1e-9)) * (height - 1))
            j = int((x - x0) / max(x1 - x0, 1e-9) * (width - 1))
            grid[i][j] = mark
    print(f"  eval loss {y1:.3f}")
    for r in grid:
        print("  |" + "".join(r))
    print("  +" + "-" * width + f"> comm events ({x0}..{x1})")
    for (label, _), mark in zip(series.items(), marks):
        print(f"    {mark} = {label}")


def main():
    acfg = AdLoCoConfig(
        num_outer_steps=12, num_inner_steps=5, lr_inner=0.05, lr_outer=0.7,
        num_init_trainers=3, nodes_per_gpu=2, initial_batch_size=2,
        merge_frequency=3, eta=0.8, max_batch=16, inner_optimizer="sgd",
        stats_probe_size=64)

    print("convex proxy (deterministic E[f] metric), 3 trainers x 2 workers")
    _, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=0)
    _, hist_a = train_adloco(quad_loss, inits, streams, acfg,
                             eval_fn=eval_fn)

    _, inits2, streams2, eval2 = quad_setup(k=3, M=2, seed=0)
    _, hist_d = train_diloco(
        quad_loss, inits2[0], streams2[:2],
        dataclasses.replace(acfg, num_outer_steps=36),
        fixed_batch=2, num_outer_steps=36, eval_fn=eval2)

    ascii_plot({
        "AdLoCo (adaptive batch + merge + switch)":
            list(zip(hist_a.comm_events, hist_a.eval_loss)),
        "DiLoCo (fixed batch)":
            list(zip(hist_d.comm_events, hist_d.eval_loss)),
    })
    print(f"\n  AdLoCo : final E[f]={hist_a.eval_loss[-1]:.4f} "
          f"after {hist_a.comm_events[-1]} comm events "
          f"({hist_a.samples[-1]} samples, final batches "
          f"{hist_a.requested_batches[-1]})")
    print(f"  DiLoCo : final E[f]={hist_d.eval_loss[-1]:.4f} "
          f"after {hist_d.comm_events[-1]} comm events "
          f"({hist_d.samples[-1]} samples, fixed batch 2)")


if __name__ == "__main__":
    main()
