"""Paper Figure 2: component ablations.

Full AdLoCo vs (−adaptive), (−merge), (−switch) on the convex proxy with
a deterministic expected-loss metric — each variant's loss trajectory and
communication budget at equal outer steps.  The convex problem makes the
per-component effects measurable without LM noise: the same qualitative
ordering the paper reports (full > each ablation) must hold on final
E[f] or comms.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco

from benchmarks.common import quad_setup, row, quad_loss


BASE = AdLoCoConfig(num_outer_steps=12, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, nodes_per_gpu=2, num_init_trainers=3,
                    initial_batch_size=2, merge_frequency=3, eta=0.8,
                    max_batch=16, inner_optimizer="sgd",
                    stats_probe_size=64)

VARIANTS = {
    "full": {},
    "no_adaptive": {"adaptive": False},
    "no_merge": {"enable_merge": False},
    "no_switch": {"enable_switch": False,
                  # cap requests so 'no accumulation' binds
                  "max_global_batch": 256},
}


def run(quick: bool = False):
    T = 8 if quick else 12
    rows = []
    results = {}
    for name, overrides in VARIANTS.items():
        _, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=1)
        acfg = dataclasses.replace(BASE, num_outer_steps=T, **overrides)
        pool, hist = train_adloco(quad_loss, inits, streams, acfg,
                                  eval_fn=eval_fn,
                                  fixed_batch=4 if name == "no_adaptive"
                                  else None)
        results[name] = (hist.eval_loss[-1], hist.comm_events[-1],
                         hist.comm_bytes[-1], hist.pool_size[-1],
                         hist.samples[-1])
        rows.append(row(
            f"fig2/{name}", 0.0,
            f"eval={hist.eval_loss[-1]:.4f};comms={hist.comm_events[-1]};"
            f"GB={hist.comm_bytes[-1] / 2**30:.4f};k_final={hist.pool_size[-1]};"
            f"samples={hist.samples[-1]}"))
    # summary orderings the paper claims
    full = results["full"]
    rows.append(row(
        "fig2/summary", 0.0,
        f"full_beats_no_adaptive_eval={full[0] <= results['no_adaptive'][0] * 1.25};"
        f"merge_contracts_pool={full[3] < results['no_merge'][3]};"
        f"switch_raises_effective_batch="
        f"{full[4] >= results['no_switch'][4]}"))
    return rows
