"""Cluster-runtime benchmark: sync vs async vs elastic outer-sync
policies on simulated heterogeneous hardware and scripted scenarios.

For each heterogeneity ratio (fastest node / slowest node speed) the
bench trains the same convex proxy under each policy and reports the
simulated wall-clock, the time spent in collectives, and the simulated
time-to-target-loss.  The paper's "fully exploits computational
clusters under dynamic workloads" claim shows up as async strictly
beating sync's time-to-target once node speeds diverge — and, on the
2-pod topology scenario sweep, whenever the cross-pod fabric gets
congested (the wire, not the worker, is the bottleneck: ACCO's case).

The adaptive scenarios (``adaptive_ramp``, ``congested_adaptive``) are
swept as *adaptive vs fixed-batch* arms instead (async policy, 2-pod
topology): the adaptive arm pays a priced batch-stats reduction every
round and its rounds lengthen as the batch ramps, the fixed arm keeps
the starting batch — the reported time-to-target difference is the
paper's adaptive-batching claim on the simulated clock.  Under the
async policy the stats phase rides the outer sync as one fused
``piggyback`` collective, so the adaptive rows also report
``stats_comm_s``/``piggyback_comm_s`` and the summary gates that the
standalone stats share is exactly zero.  Both arms are part of the
default ``--smoke`` run, so the committed ``BENCH_cluster.json``
baseline gates them on every push.

The autoscale scenarios (``autoscale_ramp``, ``preemption_storm_growth``)
are swept as *autoscaled vs fixed-pool* arms (elastic policy, both
adaptive): the autoscaled arm hands the pool to ``BandAutoscale`` —
each trainer executes its share of the requested batch and the policy
scripts joins/leaves to hold gradients-per-worker inside the band — and
the fixed-pool arm serves the same ramp on the starting pool, its
rounds stretching as the batch grows.  Time-to-target is scored on the
pool-averaged eval curve for both arms.  ``autoscale_ramp`` also runs
the predictor arms (``k_correct`` exact vs predicted batch growth),
gating the >= 2x stats-sync cut and trajectory parity at correction
rounds.  These rows ride the default ``--smoke`` run too.

  PYTHONPATH=src python benchmarks/cluster_bench.py           # full
  PYTHONPATH=src python benchmarks/cluster_bench.py --smoke   # CI job
  # CI scenario-smoke jobs: just the registered scenarios, by name
  PYTHONPATH=src python benchmarks/cluster_bench.py --smoke \\
      --scenario spot_churn --scenario bursty_congestion
  # co-scripted scenarios on the 3-level rack/pod/cluster fabric
  PYTHONPATH=src python benchmarks/cluster_bench.py --smoke --levels 3 \\
      --scenario correlated_pod_failure --scenario diurnal_congestion
  # adaptive vs fixed-batch time-to-target
  PYTHONPATH=src python benchmarks/cluster_bench.py --smoke \\
      --scenario adaptive_ramp --scenario congested_adaptive
  # autoscaled vs fixed-pool (and exact vs predicted batch growth)
  PYTHONPATH=src python benchmarks/cluster_bench.py --smoke \\
      --scenario autoscale_ramp
"""
from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import AdLoCoConfig
from repro.cluster import (BandAutoscale, ClusterEvent, ClusterSpec,
                           Topology, Trace, interleave_pods,
                           make_heterogeneous_profiles, make_pod_profiles,
                           make_rack_profiles, run_cluster)
from repro.cluster.scenarios import build_scenario, list_scenarios

from benchmarks.common import quad_setup, quad_loss, row

#: set by --trace: directory where every bench run drops its Perfetto
#: JSON (CI uploads these as artifacts and schema-checks them)
_TRACE_DIR = None


def _finish_trace(tr: Trace, tag: str) -> dict:
    """Derive the per-row observability columns from a finished trace
    and, when ``--trace DIR`` is set, export the Perfetto JSON."""
    if _TRACE_DIR is not None:
        import json
        path = os.path.join(_TRACE_DIR, f"{tag}.perfetto.json")
        with open(path, "w") as f:
            json.dump(tr.to_perfetto(), f)
    return {"utilization": tr.utilization_summary()["utilization"],
            "overlap_frac": tr.overlap_fraction()}

HET_RATIOS = (1.0, 2.0, 4.0)

#: scenarios swept over the 2-pod topology in the default run
SCENARIO_NAMES = ("baseline", "bursty_congestion", "spot_churn")

#: co-scripted scenarios swept over the 3-level fabric — their default
#: knobs target the rack/pod/cluster domain names, so they default to
#: the 3-level harness when no --levels is given
SCENARIO_NAMES3 = ("correlated_pod_failure", "diurnal_congestion",
                   "rack_flap", "straggler_cascade")

#: adaptive-batching scenarios: swept as adaptive vs fixed-batch arms
#: (async policy, 2-pod topology) instead of sync vs async — the
#: question is whether the batch ramp pays for its stats collectives
#: and longer rounds with a better time-to-target
ADAPTIVE_SCENARIOS = ("adaptive_ramp", "congested_adaptive")

#: autoscaling scenarios: swept as autoscaled vs fixed-pool arms
#: (elastic policy, 2-pod topology, both adaptive) — the question is
#: whether co-scaling the worker pool with the batch ramp (adadamp)
#: converts batch growth into wall-clock speed instead of per-round
#: slowdown.  ``autoscale_ramp`` also carries the predictor arms
#: (``k_correct`` exact vs predicted batch growth).
AUTOSCALE_SCENARIOS = ("autoscale_ramp", "preemption_storm_growth")

#: gradients-per-worker band the autoscaled arm must hold (and the
#: summary row gates); cooldown=2 round boundaries between actions
AUTOSCALE_BAND = dict(lo=2.0, hi=8.0)

#: predictor arms: exact stats reduction every K_CORRECT rounds, the
#: fitted exponential trajectory in between (>= 2x fewer stats syncs)
K_CORRECT = 4

# outer_momentum=0.5 keeps sync and async per-round trajectories
# comparable so the remaining difference is purely clock overlap.  (0.9
# is underdamped under the async one-round staleness unless
# acfg.delay_compensation=True rescales it by the measured delay — the
# regression is pinned in tests/test_cluster.py; the bench keeps 0.5 so
# both policies run the identical outer optimizer.)
BASE = AdLoCoConfig(num_outer_steps=16, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=3, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32,
                    enable_merge=False)

# toy-scale hardware: the 16-dim quadratic's rounds and its 64-byte
# all-reduces both land in the millisecond range, so compute/comm
# overlap is actually visible (v5e constants would make both ~ns)
TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)


def time_to_target(hist, target: float):
    for v, s in zip(hist.eval_loss, hist.sim_time):
        if v <= target:
            return s
    return None


def bench_policy(policy: str, ratio: float, T: int, *, seed: int = 0,
                 scenario=(), spare=0):
    acfg = dataclasses.replace(BASE, num_outer_steps=T)
    prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=seed)
    if spare:
        from benchmarks.common import QuadStream
        streams = streams + [QuadStream(prob, 100 + i, seed=seed)
                             for i in range(spare * 2)]
    n_nodes = 6 + spare * 2
    profiles = make_heterogeneous_profiles(n_nodes, ratio=ratio, **TOY)
    tr = Trace()
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy=policy, profiles=profiles,
        eval_fn=eval_fn, scenario=list(scenario), trace=tr)
    target = 0.5 * prob.noise ** 2 * 1.25
    return {
        "sim_time": rep.sim_time,
        "comm_time": rep.comm_time,
        "compute_time": rep.compute_time,
        "t2t": time_to_target(hist, target),
        "final_eval": eval_fn(pool.global_params),
        "syncs": rep.num_syncs,
        "k_final": pool.k,
        "events": [e["kind"] for e in rep.applied_events],
        **_finish_trace(tr, f"{policy}_het{ratio:g}x"),
    }


def scenario_cluster(*, seed: int = 0, spare: int = 3, ratio: float = 2.0):
    """2-pod cluster for the scenario sweep: pods homogeneous inside,
    pod 1 ``ratio``x slower, interleaved so every trainer's M=2 workers
    span both pods — each outer sync crosses the bottleneck link.
    ``spare`` trainers' worth of nodes+streams lets spot_churn rejoins
    actually land (leaves re-home their shards to the survivor, so
    spares bound rejoin capacity)."""
    from benchmarks.common import QuadStream
    k, M = 3, 2
    n = (k + spare) * M
    prob, inits, streams, eval_fn = quad_setup(k=k, M=M, seed=seed)
    streams = streams + [QuadStream(prob, 100 + i, seed=seed)
                         for i in range(spare * M)]
    profiles = make_pod_profiles([n // 2, n - n // 2], ratio=ratio, **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3)
    return prob, inits, streams, eval_fn, interleave_pods(profiles), topo


def scenario_cluster3(*, seed: int = 0, spare: int = 1, ratio: float = 2.0):
    """3-level cluster for the co-scripted sweep: 2 pods x 2 racks x
    ((k + spare) * M / 4) nodes, pod 1 ``ratio``x slower, interleaved so
    every trainer's M=2 workers span both pods — each outer sync crosses
    the rack, pod and cluster levels."""
    from benchmarks.common import QuadStream
    k, M = 3, 2
    if (k + spare) * M % 4:
        raise ValueError(f"(k + spare) * M = {(k + spare) * M} must fill "
                         f"the 4 racks evenly; pick spare accordingly")
    per_rack = (k + spare) * M // 4
    prob, inits, streams, eval_fn = quad_setup(k=k, M=M, seed=seed)
    streams = streams + [QuadStream(prob, 100 + i, seed=seed)
                         for i in range(spare * M)]
    profiles = make_rack_profiles([[per_rack, per_rack]] * 2, ratio=ratio,
                                  **TOY)
    topo = Topology.from_profiles(profiles, inter_bw=1e5,
                                  inter_latency=4e-3, pod_bw=1.5e5,
                                  pod_latency=3e-3)
    return prob, inits, streams, eval_fn, interleave_pods(profiles), topo


def bench_scenario(name: str, policy: str, T: int, *, seed: int = 0,
                   levels: int = 2):
    acfg = dataclasses.replace(BASE, num_outer_steps=T)
    cluster = scenario_cluster3 if levels == 3 else scenario_cluster
    prob, inits, streams, eval_fn, profiles, topo = cluster(seed=seed)
    tr = Trace()
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy=policy, profiles=profiles,
        network=topo, eval_fn=eval_fn, scenario=build_scenario(name),
        trace=tr)
    target = 0.5 * prob.noise ** 2 * 1.25
    return {
        "sim_time": rep.sim_time,
        "comm_time": rep.comm_time,
        "t2t": time_to_target(hist, target),
        "final_eval": eval_fn(pool.global_params),
        "syncs": rep.num_syncs,
        "k_final": pool.k,
        "events": [e["kind"] for e in rep.applied_events],
        **_finish_trace(tr, f"scenario_{name}_{policy}"),
    }


def bench_adaptive_scenario(name: str, arm: str, T: int, *,
                            seed: int = 0, levels: int = 2):
    """One arm of the adaptive sweep under the async policy:
    ``adaptive`` ramps the batch via the norm test (stats collectives
    priced every round, switch mode engaging as the ramp crosses the
    boundary), ``fixed`` pins the batch at the adaptive arm's starting
    size.  ``levels`` picks the 2-pod topology (default) or the
    3-level rack/pod/cluster tree, same as the regular sweep."""
    acfg = dataclasses.replace(BASE, num_outer_steps=T,
                               stats_estimator="microbatch",
                               max_global_batch=256,
                               adaptive=(arm == "adaptive"))
    cluster = scenario_cluster3 if levels == 3 else scenario_cluster
    prob, inits, streams, eval_fn, profiles, topo = cluster(seed=seed)
    tr = Trace()
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy="async",
        profiles=profiles, network=topo, eval_fn=eval_fn,
        scenario=build_scenario(name), trace=tr,
        fixed_batch=None if arm == "adaptive" else BASE.initial_batch_size)
    # within 5% of the noise floor — strict enough that the fixed
    # starting batch's gradient-variance plateau cannot reach it, which
    # is the paper's point: the ramp buys convergence depth the fixed
    # batch never attains, not just speed
    target = 0.5 * prob.noise ** 2 * 1.05
    b_final = max(hist.requested_batches[-1]) if hist.requested_batches \
        else 0
    # per-kind comm totals: under the async+adaptive piggyback the
    # standalone "stats" share collapses into fused "piggyback" spans
    byk = tr.overlap_by_kind()
    return {
        "sim_time": rep.sim_time,
        "comm_time": rep.comm_time,
        "t2t": time_to_target(hist, target),
        "final_eval": eval_fn(pool.global_params),
        "syncs": rep.num_syncs,
        "stats_syncs": rep.num_stats_syncs,
        "b_final": b_final,
        "accum": any(m == "accum" for ms in hist.modes for m in ms),
        "stats_comm_s": byk["stats"]["total"],
        "piggyback_comm_s": byk["piggyback"]["total"],
        "events": [e["kind"] for e in rep.applied_events],
        **_finish_trace(tr, f"adaptive_{name}_{arm}"),
    }


def run_adaptive_scenarios(T: int, names, levels=None):
    """Adaptive vs fixed-batch time-to-target per adaptive scenario."""
    rows, t2ts, piggy = [], {}, {}
    lv = levels if levels is not None else 2
    for name in names:
        for arm in ("adaptive", "fixed"):
            r = bench_adaptive_scenario(name, arm, T, levels=lv)
            t2ts[(name, arm)] = r["t2t"]
            piggy[(name, arm)] = (r["piggyback_comm_s"],
                                  r["stats_comm_s"])
            t2t = f"{r['t2t']:.4f}" if r["t2t"] is not None else "none"
            rows.append(row(
                f"cluster/scenario/{name}/{arm}", r["sim_time"] * 1e6,
                f"levels={lv};sim_s={r['sim_time']:.4f};"
                f"comm_s={r['comm_time']:.4f};"
                f"t2t_s={t2t};final={r['final_eval']:.4f};"
                f"syncs={r['syncs']};stats={r['stats_syncs']};"
                f"b_final={r['b_final']};accum={r['accum']};"
                f"utilization={r['utilization']:.4f};"
                f"overlap_frac={r['overlap_frac']:.4f};"
                f"stats_comm_s={r['stats_comm_s']:.4f};"
                f"piggyback_comm_s={r['piggyback_comm_s']:.4f};"
                f"events={'+'.join(r['events']) or 'none'}"))
    # adaptive wins when it reaches the near-noise-floor target on the
    # (simulated) wall clock and the fixed batch is either slower or
    # (typically) never gets there at all — a None fixed-arm t2t IS the
    # adaptive-batching headline
    wins = {name: (t2ts[(name, "adaptive")] is not None
                   and (t2ts[(name, "fixed")] is None
                        or t2ts[(name, "adaptive")]
                        < t2ts[(name, "fixed")]))
            for name in names}
    # the piggyback claim: every async+adaptive stats phase rides a
    # fused outer collective — the standalone stats share of comm time
    # must be exactly zero while piggyback spans carry the payload
    absorbed = {name: (piggy[(name, "adaptive")][0] > 0.0
                       and piggy[(name, "adaptive")][1] == 0.0)
                for name in names}
    rows.append(row(
        "cluster/adaptive-summary", 0.0,
        ";".join(f"adaptive_faster_{n}={wins[n]}" for n in names)
        + ";"
        + ";".join(f"piggyback_absorbs_stats_{n}={absorbed[n]}"
                   for n in names)))
    return rows


def time_to_pool_target(hist, target: float):
    """Time-to-target on the pool-averaged eval curve: the honest clock
    for pool-size dynamics, where averaging k anchors divides the noise
    floor (both autoscale arms are scored on the same curve)."""
    for v, s in zip(hist.eval_loss_pool, hist.sim_time):
        if v <= target:
            return s
    return None


def _gpw_trajectory(hist):
    """Executed gradients-per-worker per record: each trainer's
    ceil-share of the pool-max requested batch."""
    return [max(1, -(-max(bs) // k))
            for k, bs in zip(hist.pool_size, hist.requested_batches)]


def bench_autoscale_scenario(name: str, arm: str, T: int, *,
                             seed: int = 0):
    """One arm of the autoscale sweep (elastic policy, both adaptive
    with ``k_correct`` predicted growth): ``autoscaled`` hands the pool
    to BandAutoscale — each trainer executes its ceil-share of the
    requested batch and the policy scripts joins/leaves to hold
    gradients-per-worker inside the band; ``fixedpool`` keeps the
    starting pool and each trainer executes the full requested batch
    (the status-quo elastic run)."""
    # cap the ramp at hi * max-pool gradients-per-worker: the spare pool
    # bounds how far the fleet can scale, so a deeper ramp would force
    # the band open no matter what the policy does
    acfg = dataclasses.replace(BASE, num_outer_steps=T,
                               stats_estimator="microbatch",
                               max_global_batch=64, k_correct=K_CORRECT)
    prob, inits, streams, eval_fn, profiles, topo = scenario_cluster(
        seed=seed, spare=5)
    tr = Trace()
    autoscale = (BandAutoscale(cooldown_rounds=2, **AUTOSCALE_BAND)
                 if arm == "autoscaled" else None)
    spec = ClusterSpec(policy="elastic", profiles=profiles, network=topo,
                       eval_fn=eval_fn, scenario=name, trace=tr,
                       autoscale=autoscale)
    pool, hist, rep = run_cluster(quad_loss, inits, streams, acfg,
                                  spec=spec)
    # tighter than the adaptive sweep's 1.05: the 2% band is only
    # reachable late in the ramp, where the fixed pool's rounds have
    # grown ~gpw-fold long and the autoscaled pool's have not — the
    # regime the adadamp claim is about
    target = 0.5 * prob.noise ** 2 * 1.02
    gpw = _gpw_trajectory(hist)
    kinds = [e["kind"] for e in rep.applied_events]
    return {
        "sim_time": rep.sim_time,
        "comm_time": rep.comm_time,
        "t2t": time_to_pool_target(hist, target),
        "final_pool_eval": hist.eval_loss_pool[-1],
        "k_final": pool.k,
        "k_max": max(hist.pool_size),
        "gpw": gpw,
        "gpw_t": list(hist.sim_time),
        "autoscale_events": rep.num_autoscale_events,
        "joins": kinds.count("join"),
        "leaves": kinds.count("leave"),
        "skipped_joins": kinds.count("join_skipped"),
        "stats_syncs": rep.num_stats_syncs,
        "predicted_rounds": rep.num_predicted_rounds,
        **_finish_trace(tr, f"autoscale_{name}_{arm}"),
    }


def bench_predictor_arm(k_correct: int, T: int, *, seed: int = 0):
    """Fixed-pool elastic adaptive run isolating the predictor:
    ``k_correct=1`` runs the exact gradient-order stats reduction every
    round (legacy), ``k_correct>1`` fits the exponential growth
    trajectory and only pays the reduction on correction rounds."""
    acfg = dataclasses.replace(BASE, num_outer_steps=T,
                               stats_estimator="microbatch",
                               max_global_batch=256, k_correct=k_correct)
    prob, inits, streams, eval_fn, profiles, topo = scenario_cluster(
        seed=seed)
    tr = Trace()
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy="elastic",
        profiles=profiles, network=topo, eval_fn=eval_fn,
        scenario="autoscale_ramp", trace=tr)
    # per-round pool-max batch trajectory (records are per trainer;
    # collapse to one value per outer round for the parity comparison)
    traj = {}
    for r, bs in zip(hist.outer_step, hist.requested_batches):
        traj[r] = max(traj.get(r, 0), max(bs))
    target = 0.5 * prob.noise ** 2 * 1.05
    return {
        "sim_time": rep.sim_time,
        "comm_time": rep.comm_time,
        "t2t": time_to_pool_target(hist, target),
        "stats_syncs": rep.num_stats_syncs,
        "predicted_rounds": rep.num_predicted_rounds,
        "traj": traj,
        "b_final": max(traj.values()),
        **_finish_trace(tr, f"predictor_kc{k_correct}"),
    }


def run_autoscale_scenarios(T: int, names):
    """Autoscaled vs fixed-pool time-to-target per autoscale scenario,
    plus the predictor arms (exact vs predicted batch growth) when
    ``autoscale_ramp`` is in the sweep."""
    rows, t2ts, gpws = [], {}, {}
    for name in names:
        for arm in ("autoscaled", "fixedpool"):
            r = bench_autoscale_scenario(name, arm, T)
            t2ts[(name, arm)] = r["t2t"]
            if arm == "autoscaled":
                gpws[name] = (r["gpw"], r["gpw_t"])
            t2t = f"{r['t2t']:.4f}" if r["t2t"] is not None else "none"
            rows.append(row(
                f"cluster/autoscale/{name}/{arm}", r["sim_time"] * 1e6,
                f"sim_s={r['sim_time']:.4f};comm_s={r['comm_time']:.4f};"
                f"t2t_pool_s={t2t};final_pool={r['final_pool_eval']:.4f};"
                f"k_final={r['k_final']};k_max={r['k_max']};"
                f"autoscale_events={r['autoscale_events']};"
                f"joins={r['joins']};leaves={r['leaves']};"
                f"skipped_joins={r['skipped_joins']};"
                f"stats={r['stats_syncs']};"
                f"predicted={r['predicted_rounds']};"
                f"utilization={r['utilization']:.4f};"
                f"overlap_frac={r['overlap_frac']:.4f}"))
    # the adadamp claim: co-scaling the pool with the ramp reaches the
    # near-noise-floor pool target faster than serving the same ramp on
    # the starting pool (gated on the clean-fabric scenario)
    wins = {name: (t2ts[(name, "autoscaled")] is not None
                   and (t2ts[(name, "fixedpool")] is None
                        or t2ts[(name, "autoscaled")]
                        < t2ts[(name, "fixedpool")]))
            for name in names}
    # the band claim: once the ramp is underway (skip the warmup
    # transient the policy is still reacting to), the executed
    # gradients-per-worker stays inside the configured band at >= 90%
    # of round records — brief crossings while a scripted join's
    # transfer is in flight (or the cooldown holds) are the hysteresis
    # working, not a violation.  Scenarios with scripted evictions get
    # two-part scoring: (a) the band must RE-CLOSE after the last
    # eviction — a preemption physically removes workers, so gpw must
    # spike until the policy rebuilds from reclaimed capacity; when
    # leaves hoard the leaver's streams the pool gets stuck below band
    # size and never re-closes, which is exactly the verdict this
    # gates — and (b) the adherence fraction skips the reaction window
    # (first eviction -> first post-burst in-band record, paced by the
    # policy's own cooldown) but counts everything after re-close, so
    # a band that re-opens later still fails.
    lo, hi = AUTOSCALE_BAND["lo"], AUTOSCALE_BAND["hi"]
    in_band = {}
    for name in names:
        g, ts = gpws[name]
        records = list(zip(ts, g))
        tail = records[len(records) // 4:]
        evs = [e.time for e in build_scenario(name).events
               if e.kind in ("join", "leave")]
        recovered = True
        if evs:
            t_burst, t_last = min(evs), max(evs)
            t_ok = next((t for t, x in records
                         if t > t_last and lo <= x <= hi), None)
            recovered = t_ok is not None
            tail = [(t, x) for t, x in tail
                    if t < t_burst or (t_ok is not None and t >= t_ok)]
        frac = (sum(1 for _, x in tail if lo <= x <= hi) / len(tail)
                if tail else 0.0)
        in_band[name] = recovered and frac >= 0.9
    parts = [f"autoscaled_faster_{n}={wins[n]}" for n in names]
    parts += [f"gpw_in_band_{n}={in_band[n]}" for n in names]
    if "autoscale_ramp" in names:
        exact = bench_predictor_arm(1, T)
        pred = bench_predictor_arm(K_CORRECT, T)
        for tag, r in (("exact", exact), ("predicted", pred)):
            t2t = f"{r['t2t']:.4f}" if r["t2t"] is not None else "none"
            rows.append(row(
                f"cluster/predictor/{tag}", r["sim_time"] * 1e6,
                f"sim_s={r['sim_time']:.4f};comm_s={r['comm_time']:.4f};"
                f"t2t_pool_s={t2t};stats={r['stats_syncs']};"
                f"predicted={r['predicted_rounds']};"
                f"b_final={r['b_final']};"
                f"utilization={r['utilization']:.4f};"
                f"overlap_frac={r['overlap_frac']:.4f}"))
        # the predictor claim: >= 2x fewer exact stats reductions, and
        # the periodic corrections keep the predicted trajectory tied
        # to the exact one — within 2x at every correction round (the
        # fit lags the exact decisions between corrections) and
        # re-converged (15%) by the end of the ramp
        cut = (pred["stats_syncs"] > 0
               and exact["stats_syncs"] >= 2 * pred["stats_syncs"])
        corrections = [r for r in sorted(exact["traj"])
                       if (r - 1) % K_CORRECT == 0
                       and r in pred["traj"]]
        gaps = [abs(pred["traj"][r] - exact["traj"][r])
                / max(1, exact["traj"][r]) for r in corrections]
        final_gap = (abs(pred["b_final"] - exact["b_final"])
                     / max(1, exact["b_final"]))
        parity = (bool(corrections) and max(gaps) <= 1.0
                  and final_gap <= 0.15)
        parts += [f"predictor_syncs_cut_2x={cut}",
                  f"predictor_parity_at_corrections={parity}"]
    rows.append(row("cluster/autoscale-summary", 0.0, ";".join(parts)))
    return rows


def run_scenarios(T: int, names, levels=None):
    """sync vs async time-to-target per registered scenario; the
    congested 2-pod fabric is the acceptance gate.  ``levels`` of None
    picks per scenario: co-scripted generators whose default knobs name
    rack/pod/cluster domains run on the 3-level tree, the rest on the
    2-pod topology.  Adaptive scenarios dispatch to the adaptive-vs-
    fixed sweep instead of the sync-vs-async one."""
    for name in names:
        if name not in list_scenarios():
            raise SystemExit(f"unknown scenario {name!r}; registered: "
                             f"{list_scenarios()}")
    regular = [n for n in names if n not in ADAPTIVE_SCENARIOS
               and n not in AUTOSCALE_SCENARIOS]
    adaptive = [n for n in names if n in ADAPTIVE_SCENARIOS]
    autoscale = [n for n in names if n in AUTOSCALE_SCENARIOS]
    rows, t2ts, overlaps = [], {}, {}
    for name in regular:
        lv = levels if levels is not None else (
            3 if name in SCENARIO_NAMES3 else 2)
        for policy in ("sync", "async"):
            r = bench_scenario(name, policy, T, levels=lv)
            t2ts[(name, policy)] = r["t2t"]
            overlaps[(name, policy)] = r["overlap_frac"]
            t2t = f"{r['t2t']:.4f}" if r["t2t"] is not None else "none"
            rows.append(row(
                f"cluster/scenario/{name}/{policy}", r["sim_time"] * 1e6,
                f"levels={lv};sim_s={r['sim_time']:.4f};"
                f"comm_s={r['comm_time']:.4f};"
                f"t2t_s={t2t};final={r['final_eval']:.4f};"
                f"syncs={r['syncs']};k_final={r['k_final']};"
                f"utilization={r['utilization']:.4f};"
                f"overlap_frac={r['overlap_frac']:.4f};"
                f"events={'+'.join(r['events']) or 'none'}"))
    if regular:
        wins = {name: (t2ts[(name, "async")] is not None
                       and t2ts[(name, "sync")] is not None
                       and t2ts[(name, "async")] < t2ts[(name, "sync")])
                for name in regular}
        # the traced counterpart of the wins: async must actually hide
        # collectives behind compute (sync is 0.0 by construction)
        olap = {name: overlaps[(name, "async")] > overlaps[(name, "sync")]
                for name in regular}
        rows.append(row(
            "cluster/scenario-summary", 0.0,
            ";".join(f"async_faster_{n}={wins[n]}" for n in regular)
            + ";"
            + ";".join(f"async_overlap_gt_sync_{n}={olap[n]}"
                       for n in regular)))
    if adaptive:
        # the async piggyback makes every batch plan one round stale,
        # so the ramp needs ~3x the rounds of the fixed-policy sweeps
        # to cross the switch boundary, reach the noise-floor target
        # and show the adaptive-vs-fixed win the summary row gates
        rows.extend(run_adaptive_scenarios(3 * T, adaptive, levels))
    if autoscale:
        # same extended horizon: the pool has to ramp, the band policy
        # has to act, and the predictor needs several correction rounds
        rows.extend(run_autoscale_scenarios(3 * T, autoscale))
    return rows


def run(quick: bool = False, scenarios=None, levels=None):
    T = 8 if quick else 16
    if scenarios is not None:        # scenario-only mode (CI smoke jobs)
        return run_scenarios(T, scenarios, levels)
    rows = []
    t2ts = {}
    overlaps = {}
    for ratio in HET_RATIOS:
        for policy in ("sync", "async"):
            r = bench_policy(policy, ratio, T)
            t2ts[(policy, ratio)] = r["t2t"]
            overlaps[(policy, ratio)] = r["overlap_frac"]
            t2t = f"{r['t2t']:.4f}" if r["t2t"] is not None else "none"
            rows.append(row(
                f"cluster/{policy}/het{ratio:g}x", r["sim_time"] * 1e6,
                f"sim_s={r['sim_time']:.4f};comm_s={r['comm_time']:.4f};"
                f"t2t_s={t2t};final={r['final_eval']:.4f};"
                f"syncs={r['syncs']};"
                f"utilization={r['utilization']:.4f};"
                f"overlap_frac={r['overlap_frac']:.4f}"))

    # elastic scenario at 2x heterogeneity: a straggler burst, one
    # trainer leaves, a fresh one joins on spare nodes
    scen = [ClusterEvent(time=0.01, kind="slowdown", node=5, factor=4.0,
                         duration=0.2),
            ClusterEvent(time=0.05, kind="leave"),
            ClusterEvent(time=0.15, kind="join")]
    r = bench_policy("elastic", 2.0, T, scenario=scen, spare=1)
    rows.append(row(
        "cluster/elastic/het2x", r["sim_time"] * 1e6,
        f"sim_s={r['sim_time']:.4f};comm_s={r['comm_time']:.4f};"
        f"final={r['final_eval']:.4f};k_final={r['k_final']};"
        f"utilization={r['utilization']:.4f};"
        f"overlap_frac={r['overlap_frac']:.4f};"
        f"events={'+'.join(r['events'])}"))

    # the acceptance headline: async strictly faster to target once node
    # speeds differ by >= 2x — and, on the traced schedule, async must
    # show strictly higher collective/compute overlap at every ratio
    # (sync is a barrier: its overlap fraction is exactly 0)
    wins = {ratio: (t2ts[("async", ratio)] is not None
                    and t2ts[("sync", ratio)] is not None
                    and t2ts[("async", ratio)] < t2ts[("sync", ratio)])
            for ratio in HET_RATIOS}
    olap = {ratio: overlaps[("async", ratio)] > overlaps[("sync", ratio)]
            for ratio in HET_RATIOS}
    rows.append(row(
        "cluster/summary", 0.0,
        f"async_faster_to_target_1x={wins[1.0]};"
        f"async_faster_to_target_2x={wins[2.0]};"
        f"async_faster_to_target_4x={wins[4.0]};"
        f"async_overlap_gt_sync_1x={olap[1.0]};"
        f"async_overlap_gt_sync_2x={olap[2.0]};"
        f"async_overlap_gt_sync_4x={olap[4.0]}"))

    # adaptive vs fixed-batch time-to-target: part of the smoke run so
    # the committed BENCH_cluster.json baseline gates it on every push
    rows.extend(run_scenarios(T, ADAPTIVE_SCENARIOS))

    # autoscaled vs fixed-pool (and exact vs predicted batch growth):
    # also part of the smoke run, gated by the committed baseline
    rows.extend(run_scenarios(T, AUTOSCALE_SCENARIOS))

    if not quick:                    # CI covers these via --scenario (the
        rows.extend(run_scenarios(T, SCENARIO_NAMES))  # scenario-smoke jobs)
        rows.extend(run_scenarios(T, SCENARIO_NAMES3))
    return rows


def main(argv=None) -> int:
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI run (fewer outer steps)")
    ap.add_argument("--scenario", action="append", metavar="NAME",
                    help="run only the named registered scenario(s) "
                         "(repeatable); skips the heterogeneity sweep")
    ap.add_argument("--levels", type=int, choices=(2, 3), default=None,
                    help="fabric depth for --scenario runs: 2 = pod "
                         "topology, 3 = rack/pod/cluster tree (default: "
                         "3 for the co-scripted scenarios, else 2)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the sweep rows as JSON — CI uploads "
                         "this as a workflow artifact and diffs it "
                         "against the committed BENCH_cluster.json "
                         "baseline (simulated timings are deterministic "
                         "floats, so the file is reproducible)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="compare the sweep rows against a stored "
                         "baseline JSON and fail on any drift (the perf "
                         "trajectory gate)")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="write every bench run's Perfetto trace JSON "
                         "into DIR (CI uploads these as artifacts and "
                         "schema-checks them with trace_report "
                         "--validate)")
    args = ap.parse_args(argv)
    if args.trace:
        os.makedirs(args.trace, exist_ok=True)
        global _TRACE_DIR
        _TRACE_DIR = args.trace
    print("name,us_per_call,derived")
    ok = True
    rows = run(quick=args.smoke, scenarios=args.scenario,
               levels=args.levels)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"",
              flush=True)
        if r["name"] == "cluster/summary":
            ok = ok and ("async_faster_to_target_2x=True" in r["derived"]
                         and "async_faster_to_target_4x=True"
                         in r["derived"])
        if r["name"] == "cluster/scenario-summary":
            # acceptance gate: async must strictly win time-to-target on
            # the congested fabric whenever that scenario was run
            if "async_faster_bursty_congestion" in r["derived"]:
                ok = ok and ("async_faster_bursty_congestion=True"
                             in r["derived"])
        if r["name"] in ("cluster/summary", "cluster/scenario-summary"):
            # observability gate: async must show strictly higher
            # collective/compute overlap than sync on every sweep run
            ok = ok and all(
                kv.split("=")[1] == "True"
                for kv in r["derived"].split(";")
                if kv.startswith("async_overlap_gt_sync_"))
        if r["name"] == "cluster/adaptive-summary":
            # adaptive batching must win the (simulated) wall clock to
            # target on every adaptive scenario, and piggybacking must
            # have absorbed every standalone stats collective
            ok = ok and all(
                kv.split("=")[1] == "True"
                for kv in r["derived"].split(";")
                if kv.startswith(("adaptive_faster_",
                                  "piggyback_absorbs_stats_")))
        if r["name"] == "cluster/autoscale-summary":
            # autoscaling must win time-to-target on the clean ramp,
            # hold gradients-per-worker inside the band on EVERY
            # autoscale scenario — preemption storm included, now that
            # scripted leaves return the leaver's full capacity slice
            # (nodes and streams) to the spare pools and the band can
            # re-close after churn — and the predictor must cut stats
            # syncs >= 2x while staying tied to the exact trajectory
            # at its correction rounds.
            ok = ok and all(
                kv.split("=")[1] == "True"
                for kv in r["derived"].split(";")
                if kv.startswith(("autoscaled_faster_autoscale_ramp",
                                  "gpw_in_band_",
                                  "predictor_")))
    # read the baseline BEFORE writing --json: if both flags resolve to
    # the same file (case-insensitive filesystems!), writing first would
    # clobber the baseline and the gate would compare it to itself
    base = None
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
    if args.json:
        blob = {"bench": "cluster_bench",
                "args": {"smoke": bool(args.smoke),
                         "scenario": args.scenario,
                         "levels": args.levels},
                "ok": ok, "rows": rows}
        with open(args.json, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
            f.write("\n")
    if base is not None:
        # row set/order and the boolean summary verdicts must match
        # exactly; simulated times get a 5% band because the adaptive
        # runs fold jax numerics (matmuls) into the clock and CPU
        # codegen differs slightly across instruction sets
        drift = []
        if [r["name"] for r in rows] != [r["name"] for r in base["rows"]]:
            drift.append("row names/order changed")
        for a, b in zip(rows, base["rows"]):
            if a["name"].endswith("summary") and a["derived"] != \
                    b["derived"]:
                drift.append(f"{a['name']}: {a['derived']!r} != "
                             f"{b['derived']!r}")
            hi = max(abs(a["us_per_call"]), abs(b["us_per_call"]), 1e-9)
            if abs(a["us_per_call"] - b["us_per_call"]) / hi > 0.05:
                drift.append(f"{a['name']}: {a['us_per_call']:.1f}us vs "
                             f"baseline {b['us_per_call']:.1f}us")
        if drift:
            flags = (["--smoke"] if args.smoke else []) \
                + [f"--scenario {s}" for s in (args.scenario or [])] \
                + ([f"--levels {args.levels}"] if args.levels else [])
            print(f"BASELINE DRIFT vs {args.baseline}:\n  "
                  + "\n  ".join(drift)
                  + "\nIf the cost-model/scheduler change is intended, "
                  f"regenerate with:\n"
                  f"  PYTHONPATH=src python benchmarks/cluster_bench.py "
                  f"{' '.join(flags)} --json {args.baseline}\n"
                  f"and commit the diff.")
            return 1
        print(f"baseline OK: {len(rows)} rows within tolerance of "
              f"{args.baseline}")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
