"""Cluster-runtime benchmark: sync vs async vs elastic outer-sync
policies on simulated heterogeneous hardware.

For each heterogeneity ratio (fastest node / slowest node speed) the
bench trains the same convex proxy under each policy and reports the
simulated wall-clock, the time spent in collectives, and the simulated
time-to-target-loss.  The paper's "fully exploits computational
clusters under dynamic workloads" claim shows up as async strictly
beating sync's time-to-target once node speeds diverge.

  PYTHONPATH=src python benchmarks/cluster_bench.py           # full
  PYTHONPATH=src python benchmarks/cluster_bench.py --smoke   # CI job
"""
from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs.base import AdLoCoConfig
from repro.cluster import (ClusterEvent, make_heterogeneous_profiles,
                           run_cluster)

from benchmarks.common import quad_setup, quad_loss, row

HET_RATIOS = (1.0, 2.0, 4.0)

# outer_momentum=0.5: high Nesterov momentum (0.9) is underdamped under
# the async policy's one-round staleness (see repro.cluster docstring);
# 0.5 keeps sync and async per-round trajectories comparable so the
# remaining difference is purely clock overlap.
BASE = AdLoCoConfig(num_outer_steps=16, num_inner_steps=5, lr_inner=0.05,
                    lr_outer=0.7, outer_momentum=0.5, nodes_per_gpu=2,
                    num_init_trainers=3, initial_batch_size=2,
                    merge_frequency=3, eta=0.8, max_batch=16,
                    inner_optimizer="sgd", stats_probe_size=32,
                    enable_merge=False)

# toy-scale hardware: the 16-dim quadratic's rounds and its 64-byte
# all-reduces both land in the millisecond range, so compute/comm
# overlap is actually visible (v5e constants would make both ~ns)
TOY = dict(flops=1e6, hbm_bw=1e9, link_bw=2e5, link_latency=2e-3)


def time_to_target(hist, target: float):
    for v, s in zip(hist.eval_loss, hist.sim_time):
        if v <= target:
            return s
    return None


def bench_policy(policy: str, ratio: float, T: int, *, seed: int = 0,
                 scenario=(), spare=0):
    acfg = dataclasses.replace(BASE, num_outer_steps=T)
    prob, inits, streams, eval_fn = quad_setup(k=3, M=2, seed=seed)
    if spare:
        from benchmarks.common import QuadStream
        streams = streams + [QuadStream(prob, 100 + i, seed=seed)
                             for i in range(spare * 2)]
    n_nodes = 6 + spare * 2
    profiles = make_heterogeneous_profiles(n_nodes, ratio=ratio, **TOY)
    pool, hist, rep = run_cluster(
        quad_loss, inits, streams, acfg, policy=policy, profiles=profiles,
        eval_fn=eval_fn, scenario=list(scenario))
    target = 0.5 * prob.noise ** 2 * 1.25
    return {
        "sim_time": rep.sim_time,
        "comm_time": rep.comm_time,
        "compute_time": rep.compute_time,
        "t2t": time_to_target(hist, target),
        "final_eval": eval_fn(pool.global_params),
        "syncs": rep.num_syncs,
        "k_final": pool.k,
        "events": [e["kind"] for e in rep.applied_events],
    }


def run(quick: bool = False):
    T = 8 if quick else 16
    rows = []
    t2ts = {}
    for ratio in HET_RATIOS:
        for policy in ("sync", "async"):
            r = bench_policy(policy, ratio, T)
            t2ts[(policy, ratio)] = r["t2t"]
            t2t = f"{r['t2t']:.4f}" if r["t2t"] is not None else "none"
            rows.append(row(
                f"cluster/{policy}/het{ratio:g}x", r["sim_time"] * 1e6,
                f"sim_s={r['sim_time']:.4f};comm_s={r['comm_time']:.4f};"
                f"t2t_s={t2t};final={r['final_eval']:.4f};"
                f"syncs={r['syncs']}"))

    # elastic scenario at 2x heterogeneity: a straggler burst, one
    # trainer leaves, a fresh one joins on spare nodes
    scen = [ClusterEvent(time=0.01, kind="slowdown", node=5, factor=4.0,
                         duration=0.2),
            ClusterEvent(time=0.05, kind="leave"),
            ClusterEvent(time=0.15, kind="join")]
    r = bench_policy("elastic", 2.0, T, scenario=scen, spare=1)
    rows.append(row(
        "cluster/elastic/het2x", r["sim_time"] * 1e6,
        f"sim_s={r['sim_time']:.4f};comm_s={r['comm_time']:.4f};"
        f"final={r['final_eval']:.4f};k_final={r['k_final']};"
        f"events={'+'.join(r['events'])}"))

    # the acceptance headline: async strictly faster to target once node
    # speeds differ by >= 2x
    wins = {ratio: (t2ts[("async", ratio)] is not None
                    and t2ts[("sync", ratio)] is not None
                    and t2ts[("async", ratio)] < t2ts[("sync", ratio)])
            for ratio in HET_RATIOS}
    rows.append(row(
        "cluster/summary", 0.0,
        f"async_faster_to_target_1x={wins[1.0]};"
        f"async_faster_to_target_2x={wins[2.0]};"
        f"async_faster_to_target_4x={wins[4.0]}"))
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI run (fewer outer steps)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    ok = True
    for r in run(quick=args.smoke):
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"",
              flush=True)
        if r["name"] == "cluster/summary":
            ok = ("async_faster_to_target_2x=True" in r["derived"]
                  and "async_faster_to_target_4x=True" in r["derived"])
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
