"""Paper Figure 1: AdLoCo vs DiLoCo convergence & communication
efficiency.

Trains the paper's model family (reduced MicroLlama on the synthetic
C4-stand-in stream) under AdLoCo and under vanilla fixed-batch DiLoCo
with identical shards/eval, and reports:

  * eval-loss-to-target speedup (samples and communications),
  * final eval loss at equal outer budget,
  * median wall time per outer round.
"""
from __future__ import annotations

import dataclasses
import time

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco, train_diloco

from benchmarks.common import lm_setup, row, to_target


def run(quick: bool = False):
    T = 6 if quick else 10
    H = 4 if quick else 6
    cfg, inits, streams, loss_fn, eval_fn = lm_setup(k=2, M=2)
    acfg = AdLoCoConfig(
        num_outer_steps=T, num_inner_steps=H, lr_inner=3e-4, lr_outer=0.5,
        num_init_trainers=2, nodes_per_gpu=2, initial_batch_size=2,
        merge_frequency=3, eta=0.8, max_batch=16, stats_probe_size=16)

    t0 = time.time()
    pool_a, hist_a = train_adloco(loss_fn, inits, streams, acfg,
                                  eval_fn=eval_fn)
    t_adloco = time.time() - t0

    # vanilla DiLoCo: one trainer, fixed batch, same worker count
    cfg2, inits2, streams2, loss2, eval2 = lm_setup(k=2, M=2)
    t0 = time.time()
    pool_d, hist_d = train_diloco(
        loss2, inits2[0], streams2[:2],
        dataclasses.replace(acfg, nodes_per_gpu=2),
        fixed_batch=2, num_outer_steps=3 * T, eval_fn=eval2)
    t_diloco = time.time() - t0

    # target: the worse of the two final losses (both must reach it)
    target = max(hist_a.eval_loss[-1], hist_d.eval_loss[-1]) * 1.02
    s_a, ev_a, _ = to_target(hist_a, target)
    s_d, ev_d, _ = to_target(hist_d, target)

    rows = [
        row("fig1/adloco_final_eval", t_adloco / T * 1e6,
            f"eval={hist_a.eval_loss[-1]:.4f};comm_events="
            f"{hist_a.comm_events[-1]};samples={hist_a.samples[-1]}"),
        row("fig1/diloco_final_eval", t_diloco / (3 * T) * 1e6,
            f"eval={hist_d.eval_loss[-1]:.4f};comm_events="
            f"{hist_d.comm_events[-1]};samples={hist_d.samples[-1]}"),
    ]
    if ev_a and ev_d:
        rows.append(row(
            "fig1/comms_to_target_ratio", 0.0,
            f"adloco={ev_a};diloco={ev_d};ratio={ev_d / ev_a:.2f}x"))
    if s_a and s_d:
        rows.append(row(
            "fig1/samples_to_target", 0.0,
            f"adloco={s_a};diloco={s_d}"))
    rows.append(row(
        "fig1/adaptive_batch_growth", 0.0,
        f"b_first={hist_a.requested_batches[0]};"
        f"b_last={hist_a.requested_batches[-1]}"))
    return rows
