"""Shared benchmark plumbing.

Every benchmark module exposes ``run(quick=False) -> list[dict]`` with
rows ``{"name", "us_per_call", "derived"}``; ``benchmarks.run`` prints
them as the ``name,us_per_call,derived`` CSV the harness expects.
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config, reduced
from repro.data import MarkovTokenStream, QuadraticProblem


def row(name: str, us_per_call: float, derived: str) -> dict:
    return {"name": name, "us_per_call": us_per_call, "derived": derived}


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on device)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------- setups

class QuadStream:
    """Adapter: QuadraticProblem -> the trainer-stream protocol."""

    def __init__(self, prob: QuadraticProblem, shard: int, seed: int = 0):
        self.prob = prob
        self.rng = np.random.default_rng(np.random.SeedSequence([seed, shard]))

    def next_batch(self, b):
        A, y = self.prob.sample(b, self.rng)
        return {"A": A, "y": y}


def quad_loss(params, batch):
    r = batch["A"] @ params["x"] - batch["y"]
    return 0.5 * jnp.mean(jnp.square(r)), {}


def quad_setup(k: int = 3, M: int = 2, dim: int = 16, noise: float = 2.0,
               seed: int = 0):
    prob = QuadraticProblem(dim=dim, noise=noise, seed=seed)
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    inits = [{"x": jax.random.normal(kk, (dim,))} for kk in keys]
    streams = [QuadStream(prob, i, seed=seed) for i in range(k * M)]
    eval_fn = lambda p: 0.5 * float(  # noqa: E731  — deterministic E[f]
        jnp.sum(jnp.square(p["x"] - prob.x_star))) + 0.5 * prob.noise ** 2
    return prob, inits, streams, eval_fn


def lm_setup(k: int = 2, M: int = 2, seq_len: int = 32, seed: int = 0):
    """Reduced microllama (the paper's model family) + Markov stream."""
    cfg = reduced(get_config("microllama-300m"))
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    inits = [models.init_params(cfg, kk) for kk in keys]
    streams = [MarkovTokenStream(cfg.vocab_size, seq_len, shard=i, seed=seed)
               for i in range(k * M)]
    loss_fn = lambda p, b: models.loss_fn(p, b, cfg)  # noqa: E731
    held = MarkovTokenStream(cfg.vocab_size, seq_len, shard=999,
                             seed=seed).next_batch(16)
    eval_jit = jax.jit(lambda p: loss_fn(p, held)[0])
    eval_fn = lambda p: float(eval_jit(p))  # noqa: E731
    return cfg, inits, streams, loss_fn, eval_fn


def to_target(hist, target: float):
    """(samples, comm_events, outer_step) when eval first <= target."""
    for loss, s, ev, t in zip(hist.eval_loss, hist.samples,
                              hist.comm_events, hist.outer_step):
        if loss <= target:
            return s, ev, t
    return None, None, None
