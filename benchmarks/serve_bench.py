"""Serving benchmark: dense vs paged continuous batching under traced
traffic.

For each traffic trace (``repro.serve.traffic``: steady / bursty /
flash_crowd arrival processes mirroring the cluster scenario shapes)
the bench drives the SAME materialized request set through two arms at
EQUAL cache memory (positions/layer = dense n_slots * cache_len =
paged num_blocks * block_size):

  dense   the seed fixed-slot batcher — concurrency pinned at n_slots
          because every slot preallocates worst-case rows
  paged   the block-pool batcher — short requests hold only the blocks
          they touch, so more lanes fit in the same memory

Every gated number is TICK-based and bit-deterministic (wall-clock
tokens/s is printed to stderr for humans, never gated): per-row
``us_per_call`` is the scheduler tick count (5% drift band), and the
``serve/summary`` row pins — exactly, via the committed
``BENCH_serve.json`` baseline — per-trace throughput (tokens/tick),
p50/p99 request latency in ticks, peak concurrency, plus the
acceptance booleans: the paged arm sustains strictly more concurrent
requests than dense on every trace, leaks no blocks, and matches the
dense batcher AND per-request ``serve.generate`` token-for-token.

  PYTHONPATH=src python benchmarks/serve_bench.py            # full
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke    # CI lane
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke \\
      --json serve.json --baseline BENCH_serve.json
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from repro import models, serve
from repro.configs import get_config, reduced
from repro.serve import traffic
from repro.serve.scheduler import ContinuousBatcher, DenseBatcher, Request

from benchmarks.common import row

ARCH = "qwen3-0.6b"
TRACES = ("steady", "bursty", "flash_crowd")

# equal cache memory: 4 * 32 = 128 positions/layer on both arms; the
# paged arm spends it on 8 lanes of shared 8-token blocks instead of 4
# preallocated worst-case slots
DENSE = dict(n_slots=4, cache_len=32)
PAGED = dict(n_slots=8, cache_len=32, block_size=8, num_blocks=16,
             chunk_size=4)

_setup_cache = None


def _setup():
    global _setup_cache
    if _setup_cache is None:
        cfg = reduced(get_config(ARCH))
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        _setup_cache = (cfg, params)
    return _setup_cache


def _arrivals(trace: str, n: int):
    cfg, _ = _setup()
    arr = traffic.make_arrivals(trace, n_requests=n, seed=7,
                                prompt_lo=4, prompt_hi=12,
                                new_lo=4, new_hi=10)
    return traffic.materialize(arr, cfg.vocab_size, seed=7)


def bench_trace(trace: str, arm: str, n: int):
    cfg, params = _setup()
    cls, kw = ((DenseBatcher, DENSE) if arm == "dense"
               else (ContinuousBatcher, PAGED))
    cb = cls(params, cfg, **kw)
    t0 = time.perf_counter()
    rep = cb.run_trace(_arrivals(trace, n))
    wall = time.perf_counter() - t0
    print(f"# serve/{trace}/{arm}: {rep.tokens} tokens in {wall:.2f}s "
          f"wall ({rep.tokens / max(wall, 1e-9):.1f} tok/s)",
          file=sys.stderr, flush=True)
    leak_free = True
    if arm == "paged":
        leak_free = cb.pool.no_leak()
    outputs = {r: cb.finished[r].generated for r in cb.finished}
    return rep, leak_free, outputs


def parity_check(n: int = 4) -> bool:
    """Paged greedy output == per-request serve.generate on shared
    prompts (the dense-vs-paged match is gated per trace)."""
    cfg, params = _setup()
    import numpy as np
    rng = np.random.default_rng(9)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, (5,))))
               for _ in range(n)]
    want = [serve.generate(params, cfg, jnp.asarray([p], jnp.int32),
                           max_new_tokens=4, cache_len=32).tokens[0]
            for p in prompts]
    cb = ContinuousBatcher(params, cfg, **PAGED)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p, max_new_tokens=4))
    done = cb.run()
    return all(done[i].generated == want[i] for i in range(n))


def run(quick: bool = False):
    n = 10 if quick else 20
    rows, bits = [], []
    for trace in TRACES:
        reps, outs = {}, {}
        paged_leak_free = True
        for arm in ("dense", "paged"):
            rep, leak_free, outputs = bench_trace(trace, arm, n)
            reps[arm] = rep
            outs[arm] = outputs
            if arm == "paged":
                paged_leak_free = leak_free
            rows.append(row(
                f"serve/{trace}/{arm}", float(rep.ticks),
                f"ticks={rep.ticks};idle={rep.idle_ticks};"
                f"tokens={rep.tokens};"
                f"finished={rep.requests_finished};"
                f"tok_per_tick={rep.tokens_per_tick:.4f};"
                f"p50={rep.p50_latency:.1f};p99={rep.p99_latency:.2f};"
                f"ttft_p50={rep.p50_ttft:.1f};"
                f"maxconc={rep.max_concurrency};"
                f"occupancy={rep.mean_occupancy:.4f};"
                f"peak_blocks={rep.peak_blocks};"
                f"preempts={rep.preemptions}"))
        d, p = reps["dense"], reps["paged"]
        bits.append(
            f"{trace}_paged_tok_per_tick={p.tokens_per_tick:.4f};"
            f"{trace}_paged_p50={p.p50_latency:.1f};"
            f"{trace}_paged_p99={p.p99_latency:.2f};"
            f"{trace}_dense_p50={d.p50_latency:.1f};"
            f"{trace}_dense_p99={d.p99_latency:.2f};"
            f"{trace}_paged_more_concurrent="
            f"{p.max_concurrency > d.max_concurrency};"
            f"{trace}_no_block_leak={paged_leak_free};"
            f"{trace}_paged_matches_dense={outs['paged'] == outs['dense']}")
    bits.append(f"paged_matches_generate={parity_check()}")
    rows.append(row("serve/summary", 0.0, ";".join(bits)))
    return rows


def main(argv=None) -> int:
    import argparse
    import json
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI run (fewer requests per trace)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the sweep rows as JSON — CI uploads "
                         "this as a workflow artifact and diffs it "
                         "against the committed BENCH_serve.json "
                         "baseline (tick metrics are deterministic, so "
                         "the file is reproducible)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="compare the sweep rows against a stored "
                         "baseline JSON and fail on any drift")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    ok = True
    rows = run(quick=args.smoke)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"",
              flush=True)
        if r["name"] == "serve/summary":
            # acceptance gates: paged strictly more concurrent at equal
            # memory on every trace, no leaked blocks, token-for-token
            # parity with the dense batcher and per-request generate
            ok = ok and all(
                kv.split("=")[1] == "True"
                for kv in r["derived"].split(";")
                if kv.split("=")[0].endswith(
                    ("_paged_more_concurrent", "_no_block_leak",
                     "_paged_matches_dense"))
                or kv.split("=")[0] == "paged_matches_generate")
    # read the baseline BEFORE writing --json: if both flags resolve to
    # the same file (case-insensitive filesystems!), writing first would
    # clobber the baseline and the gate would compare it to itself
    base = None
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
    if args.json:
        blob = {"bench": "serve_bench",
                "args": {"smoke": bool(args.smoke)},
                "ok": ok, "rows": rows}
        with open(args.json, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
            f.write("\n")
    if base is not None:
        # row set/order and the summary (throughput, latency and the
        # acceptance booleans) must match exactly; tick counts get a 5%
        # band to mirror the cluster gate even though they are integers
        drift = []
        if [r["name"] for r in rows] != [r["name"] for r in base["rows"]]:
            drift.append("row names/order changed")
        for a, b in zip(rows, base["rows"]):
            if a["name"].endswith("summary") and a["derived"] != \
                    b["derived"]:
                drift.append(f"{a['name']}: {a['derived']!r} != "
                             f"{b['derived']!r}")
            hi = max(abs(a["us_per_call"]), abs(b["us_per_call"]), 1e-9)
            if abs(a["us_per_call"] - b["us_per_call"]) / hi > 0.05:
                drift.append(f"{a['name']}: {a['us_per_call']:.1f} ticks "
                             f"vs baseline {b['us_per_call']:.1f}")
        if drift:
            flags = ["--smoke"] if args.smoke else []
            print(f"BASELINE DRIFT vs {args.baseline}:\n  "
                  + "\n  ".join(drift)
                  + "\nIf the scheduler change is intended, regenerate "
                  f"with:\n"
                  f"  PYTHONPATH=src python benchmarks/serve_bench.py "
                  f"{' '.join(flags)} --json {args.baseline}\n"
                  f"and commit the diff.")
            return 1
        print(f"baseline OK: {len(rows)} rows within tolerance of "
              f"{args.baseline}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
