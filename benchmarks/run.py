"""Benchmark harness — one module per paper table/figure (+ roofline).

  PYTHONPATH=src python -m benchmarks.run             # full
  PYTHONPATH=src python -m benchmarks.run --quick
  PYTHONPATH=src python -m benchmarks.run --only fig1

Emits ``name,us_per_call,derived`` CSV.

  fig1     convergence.py        AdLoCo vs DiLoCo (paper Fig. 1)
  fig2     ablations.py          component ablations (paper Fig. 2)
  thm1     batch_growth.py       E[b_k] = Omega(k)  (Theorem 1)
  thm2     comm_complexity.py    E[C(N)] = O(ln N)  (Theorem 2)
  kernel   kernels_bench.py      Pallas kernels vs jnp oracle
  roofline roofline_table.py     dry-run roofline baselines (40 pairs x 2 meshes)
  cluster  cluster_bench.py      sync vs async vs elastic on simulated hardware
  serve    serve_bench.py        dense vs paged continuous batching under traffic
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("fig1", "benchmarks.convergence"),
    ("fig2", "benchmarks.ablations"),
    ("thm1", "benchmarks.batch_growth"),
    ("thm2", "benchmarks.comm_complexity"),
    ("kernel", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline_table"),
    ("cluster", "benchmarks.cluster_bench"),
    ("serve", "benchmarks.serve_bench"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=[k for k, _ in MODULES])
    args = ap.parse_args(argv)

    import importlib
    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if args.only and key != args.only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            for r in mod.run(quick=args.quick):
                print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"",
                      flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{key}/ERROR,0.0,\"{type(e).__name__}: {e}\"", flush=True)
        print(f"# {key} done in {time.time() - t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
