"""Roofline baseline table (deliverable g): one row per (arch x shape x
mesh) from the dry-run artifacts.  ``us_per_call`` = the roofline-bound
step time; ``derived`` = the three terms + dominant bottleneck.
"""
from __future__ import annotations

from repro.launch.roofline import baseline_rows, load_rows

from benchmarks.common import row


def run(quick: bool = False):
    rows = []
    data = baseline_rows(load_rows())
    if not data:
        return [row("roofline/missing", 0.0,
                    "no dry-run artifacts; run repro.launch.dryrun --all")]
    for r in sorted(data, key=lambda r: (r.mesh, r.arch, r.shape)):
        rows.append(row(
            f"roofline/{r.arch}__{r.shape}__{r.mesh}",
            r.bound_s * 1e6,
            f"compute_s={r.compute_s:.3g};memory_s={r.memory_s:.3g};"
            f"collective_s={r.collective_s:.3g};dominant={r.dominant};"
            f"useful_ratio={r.useful_ratio:.2f};"
            f"roofline_frac={r.roofline_fraction:.3f}"))
    return rows
