"""Kernel microbenchmarks: Pallas kernel (interpret mode on CPU) vs the
pure-jnp oracle, per representative shape.

On this CPU container the interesting number is the oracle wall time and
the max abs error between paths (the kernel's TPU perf story is the
roofline/dry-run section); both are recorded per shape/dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gradstats.ops import gradstats_reduce
from repro.kernels.gradstats.ref import gradstats_reduce_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref

from benchmarks.common import row, time_fn


def _err(a, b):
    fa = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), a)
    fb = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), b)
    la, lb = jax.tree.leaves(fa), jax.tree.leaves(fb)
    return max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(la, lb))


def run(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention (B,H,S,D) — GQA shape from qwen family
    B, H, S, D = 1, 4, 256, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    kk = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    ref = jax.jit(lambda a, b, c: flash_attention_ref(a, b, c, causal=True))
    us = time_fn(ref, q, kk, v, iters=5 if quick else 10)
    err = _err(flash_attention(q, kk, v, causal=True), ref(q, kk, v))
    rows.append(row("kernel/flash_attention_256x64", us,
                    f"max_err_vs_ref={err:.2e}"))

    # mamba scan (B,S,Di) with state 16
    Bm, Sm, Di, N = 1, 256, 128, 16
    ks = jax.random.split(key, 5)
    u = jax.random.normal(ks[0], (Bm, Sm, Di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bm, Sm, Di)) - 1)
    A_log = jax.random.normal(ks[2], (Di, N)) * 0.1
    Bmat = jax.random.normal(ks[3], (Bm, Sm, N))
    Cmat = jax.random.normal(ks[4], (Bm, Sm, N))
    refm = jax.jit(mamba_scan_ref)
    us = time_fn(refm, u, dt, A_log, Bmat, Cmat, iters=5 if quick else 10)
    err = _err(mamba_scan(u, dt, A_log, Bmat, Cmat),
               refm(u, dt, A_log, Bmat, Cmat))
    rows.append(row("kernel/mamba_scan_256x128x16", us,
                    f"max_err_vs_ref={err:.2e}"))

    # gradstats reduction (B, D)
    G = jax.random.normal(key, (32, 4096), jnp.float32)
    refg = jax.jit(gradstats_reduce_ref)
    us = time_fn(refg, G, iters=5 if quick else 10)
    a = gradstats_reduce(G)
    b = refg(G)
    err = max(float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)
                                    - jnp.asarray(y, jnp.float32))))
              for x, y in zip(a, b))
    rows.append(row("kernel/gradstats_32x4096", us,
                    f"max_err_vs_ref={err:.2e}"))
    return rows
