"""Paper Theorem 1: E[b_k] = Omega(k) under the norm test.

Runs single-trainer AdLoCo on the convex proxy (where sigma^2 and the
gradient-norm decay are controlled) and fits the measured requested-batch
sequence b_k against k: reports the linear-fit slope, the R^2, and the
ratio of linear-fit to constant-fit residuals (must favour linear).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco

from benchmarks.common import quad_setup, row, quad_loss


def run(quick: bool = False):
    T = 15 if quick else 25
    _, inits, streams, _ = quad_setup(k=1, M=1, noise=2.0)
    acfg = AdLoCoConfig(
        num_outer_steps=T, num_inner_steps=8, lr_inner=0.02, lr_outer=0.7,
        num_init_trainers=1, nodes_per_gpu=1, initial_batch_size=1,
        eta=0.6, max_batch=64, inner_optimizer="sgd",
        stats_probe_size=4096, max_global_batch=1_000_000)
    _, hist = train_adloco(quad_loss, inits[:1], streams[:1], acfg)

    b = np.array([bs[0] for bs in hist.requested_batches], float)
    k = np.arange(1, len(b) + 1, dtype=float)
    # linear fit b ~ a*k + c
    A = np.vstack([k, np.ones_like(k)]).T
    coef, res_lin, *_ = np.linalg.lstsq(A, b, rcond=None)
    res_const = float(np.sum((b - b.mean()) ** 2))
    r2 = 1.0 - float(res_lin[0]) / max(res_const, 1e-12) \
        if len(res_lin) else 1.0
    return [
        row("thm1/batch_growth_slope", 0.0,
            f"slope={coef[0]:.2f}/outer_step;r2={r2:.3f};"
            f"b_first={b[0]:.0f};b_last={b[-1]:.0f}"),
        row("thm1/monotone", 0.0,
            f"monotone={bool(np.all(np.diff(b) >= 0))};"
            f"growth_factor={b[-1] / max(b[0], 1):.1f}x"),
    ]
