"""Paper Theorem 2: E[C(N)] = O(ln N).

C(N) = sum_k b_max / b_k (communications per gradient-accumulation
iteration shrink as batches grow).  Using the measured batch sequence
from a norm-test run, fits the cumulative C against both a*ln N + c and
a*N + c; the log model must win (smaller residual).  Also reports the
empirical communications AdLoCo actually performed vs what a fixed-batch
run would need for the same sample count.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import AdLoCoConfig
from repro.core import train_adloco

from benchmarks.common import quad_setup, row, quad_loss


def run(quick: bool = False):
    T = 18 if quick else 30
    _, inits, streams, _ = quad_setup(k=1, M=1, noise=2.0)
    acfg = AdLoCoConfig(
        num_outer_steps=T, num_inner_steps=8, lr_inner=0.02, lr_outer=0.7,
        num_init_trainers=1, nodes_per_gpu=1, initial_batch_size=1,
        eta=0.6, max_batch=64, inner_optimizer="sgd",
        stats_probe_size=4096, max_global_batch=1_000_000)
    _, hist = train_adloco(quad_loss, inits[:1], streams[:1], acfg)

    H = acfg.num_inner_steps
    b_seq = np.concatenate([np.full(H, bs[0], float)
                            for bs in hist.requested_batches])
    C = np.cumsum(acfg.max_batch / np.maximum(b_seq, 1.0))
    N = np.arange(1, len(C) + 1, dtype=float)
    A_log = np.vstack([np.log(N), np.ones_like(N)]).T
    A_lin = np.vstack([N, np.ones_like(N)]).T
    fit_log, res_log, *_ = np.linalg.lstsq(A_log, C, rcond=None)
    _, res_lin, *_ = np.linalg.lstsq(A_lin, C, rcond=None)
    ratio = float(res_lin[0]) / max(float(res_log[0]), 1e-12)

    # empirical comms savings at equal samples: fixed-batch does one sync
    # per H iterations regardless; AdLoCo's larger batches mean fewer
    # iterations per sample
    samples = hist.samples[-1]
    fixed_iters = samples / acfg.initial_batch_size
    adaptive_iters = len(b_seq)
    return [
        row("thm2/logfit", 0.0,
            f"C_fits_a_lnN={fit_log[0]:.2f}*lnN+{fit_log[1]:.2f};"
            f"lin_vs_log_residual_ratio={ratio:.1f}"),
        row("thm2/iters_per_sample", 0.0,
            f"adaptive_iters={adaptive_iters};"
            f"fixed_b0_iters={fixed_iters:.0f};"
            f"savings={fixed_iters / adaptive_iters:.1f}x"),
    ]
